//! Bench: compiler-stack hot paths (the §Perf targets in EXPERIMENTS.md):
//!   * kernel analysis (Algorithms 1+2) throughput,
//!   * streaming-architecture construction,
//!   * DSE solve (branch & bound),
//!   * cycle-level simulation throughput — arena engine vs the retained
//!     naive reference (firings/s, token-ops/s),
//!   * cold-vs-reused `SimContext` cost,
//!   * serial-vs-parallel tiled simulation wall-time,
//!   * PJRT golden-model execution (when artifacts exist).
//!
//! Emits `BENCH_sim.json` (uploaded as a CI artifact) and asserts the
//! parallel-tiled smoke invariant: fanning the 2×2 `tiny_cnn` grid over
//! the work-stealing scheduler is not slower than the serial path.
//!
//! Run: `cargo bench --bench compiler_perf`

use std::time::{Duration, Instant};

use ming::analysis::classify::classify;
use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dse::ilp::{solve, DseConfig};
use ming::dataflow::build::build_streaming_design;
use ming::ir::builder::models;
use ming::ir::json;
use ming::resources::device::DeviceSpec;
use ming::runtime::golden::GoldenModel;
use ming::sim::naive::simulate_naive;
use ming::sim::{simulate, SimConfig, SimContext, SimMode};
use ming::tiling::{
    compile_tiled_fixed, simulate_tiled, simulate_tiled_parallel, simulate_tiled_with,
};
use ming::util::bench::bench;
use ming::util::prng;

fn det_input(g: &ming::ir::graph::ModelGraph) -> Vec<i32> {
    prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect()
}

/// Min wall-time of `iters` runs (min is the noise-robust statistic for
/// the serial-vs-parallel smoke comparison).
fn min_wall<T>(iters: usize, mut f: impl FnMut() -> T) -> Duration {
    let mut best = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed());
    }
    best
}

fn main() {
    let dev = DeviceSpec::kv260();

    // --- analysis ---------------------------------------------------------
    let g = models::residual(224, models::CONV_C, models::CONV_F);
    let s = bench("analysis_classify_residual224", 5, 200, || {
        g.ops.iter().map(classify).count()
    });
    println!("{}", s.summary());

    // --- build ------------------------------------------------------------
    let s = bench("build_streaming_residual224", 5, 100, || {
        build_streaming_design(&g).unwrap()
    });
    println!("{}", s.summary());

    // --- DSE --------------------------------------------------------------
    for (name, size) in [("residual", 32usize), ("feedforward", 0)] {
        let gg = models::paper_kernel(name, size).unwrap();
        let s = bench(&format!("dse_solve_{name}"), 3, 50, || {
            let mut d = build_streaming_design(&gg).unwrap();
            solve(&mut d, &DseConfig::new(dev.clone())).unwrap()
        });
        println!("{}", s.summary());
    }

    // --- simulation throughput: arena engine (fast-forward vs exact) ------
    // The default engine fast-forwards steady-state periods; the exact
    // run of the same context config'd with `SimConfig::exact()` is the
    // reference. Both simulate the identical cycle count (bit-exact), so
    // the effective simulated-cycles/s ratio is also the wall-time ratio.
    let mut conv224_arena_fps = 0.0f64;
    let mut conv224_token_ops_ps = 0.0f64;
    // (kernel, ff sim-cycles/s, exact sim-cycles/s, ff periods)
    let mut ff_rows: Vec<(String, f64, f64, u64)> = Vec::new();
    for (name, size) in [("conv_relu", 224usize), ("cascade", 224), ("linear", 0)] {
        let gg = models::paper_kernel(name, size).unwrap();
        let d = compile_with(FrameworkKind::Ming, &gg, &dev).unwrap();
        let x = det_input(&gg);
        let mut firings = 0u64;
        let mut token_ops = 0u64;
        let mut cycles = 0u64;
        let mut periods = 0u64;
        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        let s = bench(&format!("simulate_ming_{name}_{size}"), 1, 5, || {
            let rep = ctx.run(&x).unwrap();
            firings = rep.total_firings;
            token_ops = rep.token_ops;
            cycles = rep.cycles;
            periods = rep.ff.periods;
            rep.cycles
        });
        let per_sec = firings as f64 / s.mean.as_secs_f64();
        let ops_sec = token_ops as f64 / s.mean.as_secs_f64();
        let ff_cps = cycles as f64 / s.mean.as_secs_f64();
        let mut exact_ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        exact_ctx.set_config(SimConfig::exact());
        let se = bench(&format!("simulate_exact_{name}_{size}"), 1, 3, || {
            exact_ctx.run(&x).unwrap().cycles
        });
        let exact_cps = cycles as f64 / se.mean.as_secs_f64();
        println!("{}", se.summary());
        println!(
            "{}  [{:.1}M firings/s, {:.1}M token-ops/s; {:.1}M sim-cycles/s vs {:.1}M exact \
             = {:.1}x, {periods} ff periods]",
            s.summary(),
            per_sec / 1e6,
            ops_sec / 1e6,
            ff_cps / 1e6,
            exact_cps / 1e6,
            ff_cps / exact_cps.max(1.0)
        );
        if name == "conv_relu" {
            conv224_arena_fps = per_sec;
            conv224_token_ops_ps = ops_sec;
        }
        ff_rows.push((format!("{name}_{size}"), ff_cps, exact_cps, periods));
    }

    // --- arena vs the retained naive reference engine ---------------------
    // Same design, same input, same timing contract. The naive side is
    // timed like the pre-PR engine actually ran: per-call proc build
    // (weight transposition included) plus the owned-Vec data plane —
    // exactly what every simulate() used to pay — while the arena side
    // reuses its context the way callers now do. `speedup_vs_naive` is
    // therefore the end-to-end pre-PR-vs-now per-run ratio, not a pure
    // data-plane microbenchmark.
    let naive_fps = {
        let gg = models::paper_kernel("conv_relu", 224).unwrap();
        let d = compile_with(FrameworkKind::Ming, &gg, &dev).unwrap();
        let x = det_input(&gg);
        let mut firings = 0u64;
        let s = bench("simulate_naive_conv_relu_224", 1, 3, || {
            let rep = simulate_naive(&d, &x, SimMode::Dataflow).unwrap();
            firings = rep.total_firings;
            rep.cycles
        });
        let per_sec = firings as f64 / s.mean.as_secs_f64();
        println!("{}  [{:.1}M firings/s]", s.summary(), per_sec / 1e6);
        per_sec
    };
    let speedup_vs_naive = conv224_arena_fps / naive_fps.max(1.0);
    println!("arena-vs-naive speedup on conv_relu_224: {speedup_vs_naive:.1}x");

    // --- cold vs reused SimContext ----------------------------------------
    // Cold pays build_proc (weight transposition, line-buffer allocs)
    // per run; reused pays it once — the per-cell win of tiled runs.
    let (ctx_cold_ms, ctx_reused_ms) = {
        let gg = models::cascade(64, models::CONV_C, models::CONV_F);
        let d = compile_with(FrameworkKind::Ming, &gg, &dev).unwrap();
        let x = det_input(&gg);
        let cold = bench("sim_ctx_cold_cascade64", 1, 10, || {
            simulate(&d, &x, SimMode::Dataflow).unwrap().cycles
        });
        let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
        let reused = bench("sim_ctx_reused_cascade64", 1, 10, || ctx.run(&x).unwrap().cycles);
        println!("{}", cold.summary());
        println!("{}", reused.summary());
        (cold.mean.as_secs_f64() * 1e3, reused.mean.as_secs_f64() * 1e3)
    };

    // --- tiled: serial vs parallel ----------------------------------------
    // A vgg3-style 3-conv block, grid-tiled 2x2 — the oversized-showcase
    // shape at a CI-simulable size. Serial reuses one context across
    // cells; parallel fans cells over a scheduler.
    let workers = ming::coordinator::sched::default_size().max(2);
    let pool = ming::coordinator::Scheduler::new(workers);
    let (tiled_serial_ms, tiled_parallel_ms, ctx_builds, vgg_ff_speedup) = {
        let gg = models::vgg_block(128, 16, 3);
        let x = det_input(&gg);
        let tc = compile_tiled_fixed(&gg, &DseConfig::new(dev.clone()), 2, 2).unwrap();
        let serial = min_wall(3, || simulate_tiled(&tc, &x).unwrap().cycles);
        let exact =
            min_wall(2, || simulate_tiled_with(&tc, &x, SimConfig::exact()).unwrap().cycles);
        let ff_speedup = exact.as_secs_f64() / serial.as_secs_f64().max(1e-9);
        let mut ctx_builds = 0u64;
        let parallel = min_wall(3, || {
            let rep = simulate_tiled_parallel(&tc, &x, &pool).unwrap();
            ctx_builds = rep.ctx_builds;
            rep.cycles
        });
        println!(
            "tiled_vgg3_128_2x2: serial {:.1}ms (exact {:.1}ms, ff {ff_speedup:.1}x), \
             parallel({workers}) {:.1}ms ({:.2}x, {ctx_builds} ctx builds via the shared pool)",
            serial.as_secs_f64() * 1e3,
            exact.as_secs_f64() * 1e3,
            parallel.as_secs_f64() * 1e3,
            serial.as_secs_f64() / parallel.as_secs_f64().max(1e-9)
        );
        (serial.as_secs_f64() * 1e3, parallel.as_secs_f64() * 1e3, ctx_builds, ff_speedup)
    };

    // --- smoke: parallel must not be slower on the 2x2 tiny_cnn case ------
    let (smoke_serial_ms, smoke_parallel_ms) = {
        let gg = models::tiny_cnn(96, 8, 8);
        let x = det_input(&gg);
        let tc = compile_tiled_fixed(&gg, &DseConfig::new(dev.clone()), 2, 2).unwrap();
        let serial = min_wall(5, || simulate_tiled(&tc, &x).unwrap().cycles);
        let parallel = min_wall(5, || simulate_tiled_parallel(&tc, &x, &pool).unwrap().cycles);
        println!(
            "smoke tiny_cnn_96 2x2: serial {:.1}ms, parallel({workers}) {:.1}ms",
            serial.as_secs_f64() * 1e3,
            parallel.as_secs_f64() * 1e3
        );
        // min-of-5 sampling plus 15% headroom absorbs shared-runner
        // scheduler noise; with >= 2 workers and 4 independent cells of
        // ~10ms each the parallel path should win outright, so a real
        // fan-out regression still trips this
        assert!(
            parallel.as_secs_f64() <= serial.as_secs_f64() * 1.15,
            "parallel tiled simulation regressed: {:.1}ms vs serial {:.1}ms",
            parallel.as_secs_f64() * 1e3,
            serial.as_secs_f64() * 1e3
        );
        (serial.as_secs_f64() * 1e3, parallel.as_secs_f64() * 1e3)
    };

    let ff_json = ff_rows
        .iter()
        .map(|(name, ffc, exc, periods)| {
            format!(
                "\"{name}\":{{\"sim_cycles_per_sec\":{ffc:.0},\
                 \"exact_sim_cycles_per_sec\":{exc:.0},\
                 \"speedup\":{:.2},\"ff_periods\":{periods}}}",
                ffc / exc.max(1.0)
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let json = format!(
        "{{\"bench\":\"sim\",\
         \"simulate_ming_conv_relu_224\":{{\
         \"arena_firings_per_sec\":{conv224_arena_fps:.0},\
         \"naive_firings_per_sec\":{naive_fps:.0},\
         \"speedup_vs_naive\":{speedup_vs_naive:.2},\
         \"token_ops_per_sec\":{conv224_token_ops_ps:.0}}},\
         \"fast_forward\":{{{ff_json},\
         \"vgg3_128_2x2\":{{\"speedup\":{vgg_ff_speedup:.2}}}}},\
         \"sim_context\":{{\"cold_ms\":{ctx_cold_ms:.3},\"reused_ms\":{ctx_reused_ms:.3},\
         \"reuse_speedup\":{:.2}}},\
         \"tiled_vgg3_128_2x2\":{{\"workers\":{workers},\
         \"serial_ms\":{tiled_serial_ms:.3},\"parallel_ms\":{tiled_parallel_ms:.3},\
         \"parallel_speedup\":{:.2},\"ctx_builds\":{ctx_builds}}},\
         \"smoke_tiny_cnn_96_2x2\":{{\"serial_ms\":{smoke_serial_ms:.3},\
         \"parallel_ms\":{smoke_parallel_ms:.3}}}}}",
        ctx_cold_ms / ctx_reused_ms.max(1e-9),
        tiled_serial_ms / tiled_parallel_ms.max(1e-9),
    );
    std::fs::write("BENCH_sim.json", format!("{json}\n")).expect("writing BENCH_sim.json");
    println!("wrote BENCH_sim.json");

    // --- perf-regression gate (BENCH_baseline.json) -----------------------
    // Committed floors, deliberately conservative: the job fails only when
    // a gated throughput metric drops below 80% of its baseline value.
    // Re-baseline by copying numbers from a CI BENCH_sim.json artifact.
    // MING_BENCH_NO_GATE=1 skips the gate (shared/loaded dev machines).
    if std::env::var_os("MING_BENCH_NO_GATE").is_some() {
        println!("perf gate: skipped (MING_BENCH_NO_GATE=1)");
    } else if let Ok(text) = std::fs::read_to_string("BENCH_baseline.json") {
        let base = json::parse(&text).expect("BENCH_baseline.json must parse");
        let baseline = |path: &str| -> f64 {
            let mut node = &base;
            for seg in path.split('.') {
                node = node.get(seg).unwrap_or_else(|e| panic!("baseline {path}: {e}"));
            }
            node.as_f64().unwrap_or_else(|e| panic!("baseline {path}: {e}"))
        };
        let ff_row = |key: &str| {
            ff_rows.iter().find(|r| r.0 == key).map(|r| (r.1, r.1 / r.2.max(1.0))).unwrap()
        };
        let (conv_cps, conv_speedup) = ff_row("conv_relu_224");
        let (cascade_cps, cascade_speedup) = ff_row("cascade_224");
        let gates = [
            ("simulate_ming_conv_relu_224.arena_firings_per_sec", conv224_arena_fps),
            ("simulate_ming_conv_relu_224.speedup_vs_naive", speedup_vs_naive),
            ("fast_forward.conv_relu_224.sim_cycles_per_sec", conv_cps),
            ("fast_forward.conv_relu_224.speedup", conv_speedup),
            ("fast_forward.cascade_224.sim_cycles_per_sec", cascade_cps),
            ("fast_forward.cascade_224.speedup", cascade_speedup),
            ("fast_forward.vgg3_128_2x2.speedup", vgg_ff_speedup),
        ];
        let mut failed = false;
        for (path, cur) in gates {
            let floor = baseline(path) * 0.8;
            if cur < floor {
                eprintln!("perf gate FAIL {path}: {cur:.2} < floor {floor:.2} (0.8x baseline)");
                failed = true;
            } else {
                println!("perf gate ok   {path}: {cur:.2} >= floor {floor:.2}");
            }
        }
        assert!(!failed, "simulation throughput regressed >20% vs BENCH_baseline.json");
    } else {
        println!("perf gate: BENCH_baseline.json not found, skipping");
    }

    // --- golden model (PJRT) ------------------------------------------------
    if let Ok(gm) = GoldenModel::open_default() {
        if gm.available("conv_relu_32") {
            let x: Vec<i32> =
                prng::det_tensor(prng::SEED_INPUT, 32 * 32 * 8).iter().map(|&v| v as i32).collect();
            // first call compiles; bench the warm path
            gm.run("conv_relu_32", &x).unwrap();
            let s = bench("pjrt_golden_conv_relu_32", 2, 20, || {
                gm.run("conv_relu_32", &x).unwrap()
            });
            println!("{}", s.summary());
        }
    } else {
        println!("pjrt_golden_*: skipped (run `make artifacts`)");
    }
}
