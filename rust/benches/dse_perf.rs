//! Bench: the cold compile path after the parallel-DSE rework:
//!   * cold solve throughput over the Table-II paper kernels (build +
//!     branch-and-bound, no cache),
//!   * dominance-prune ratio on those kernels (`dse.dominance_pruned` /
//!     `dse.candidates` metric deltas),
//!   * parallel-vs-serial branch-and-bound speedup on a synthetic
//!     wide-lattice MLP under a tight DSP cap (the filter is disabled
//!     for this pair so the comparison isolates raw search parallelism;
//!     the filtered serial time is reported alongside for scale),
//!   * serial-vs-speculative tile-grid search wall-time on the
//!     BRAM-starved conv fallback scenario,
//!   * cold-vs-warm sweep wall time on a multi-size same-kernel
//!     workload (node-front memoization + repair-based incumbent
//!     seeding), with the front-cache hit rate and the warm-seed
//!     prune ratio on explored nodes.
//!
//! Emits `BENCH_dse.json` (uploaded as a CI artifact) and gates against
//! the committed `BENCH_dse_baseline.json` floors (0.8x baseline, same
//! `MING_BENCH_NO_GATE=1` escape hatch as the sim gate). The
//! parallelism gates only arm on machines with >= 4 cores.
//!
//! Run: `cargo bench --bench dse_perf`

use std::sync::Arc;
use std::time::{Duration, Instant};

use ming::dataflow::build::build_streaming_design;
use ming::dse::ilp::{solve, DseConfig};
use ming::dse::WarmStart;
use ming::ir::builder::{models, GraphBuilder};
use ming::ir::graph::ModelGraph;
use ming::ir::json;
use ming::ir::types::DType;
use ming::resources::device::DeviceSpec;
use ming::tiling::compile_tiled;
use ming::util::bench::bench;

/// Min wall-time of `iters` runs (min is the noise-robust statistic for
/// serial-vs-parallel comparisons).
fn min_wall<T>(iters: usize, mut f: impl FnMut() -> T) -> Duration {
    let mut best = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        best = best.min(t0.elapsed());
    }
    best
}

/// The synthetic wide-lattice workload: a square MLP whose matmul
/// dimensions have many divisors, so every layer contributes a dense
/// (unroll_par × unroll_red) candidate lattice and the branch-and-bound
/// has a genuinely wide tree to split across workers.
fn wide_mlp(layers: usize, dim: usize) -> ModelGraph {
    let mut b = GraphBuilder::new(format!("wide_mlp{layers}x{dim}"));
    let x = b.input("x", vec![dim, dim], DType::I8);
    let mut cur = x;
    for li in 0..layers {
        let w = b.det_weight(&format!("w{li}"), vec![dim, dim], 100 + li as u64);
        let acc = b.linear(&format!("mm{li}"), cur, w);
        cur = b.relu_requant(&format!("rr{li}"), acc);
    }
    b.mark_output(cur);
    let g = b.finish();
    g.validate().unwrap();
    g
}

fn main() {
    let dev = DeviceSpec::kv260();
    let metrics = ming::obs::metrics::global();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // --- cold solve throughput + dominance ratio (Table-II kernels) -------
    let workloads = models::table2_workloads();
    let c0 = metrics.get("dse.candidates");
    let p0 = metrics.get("dse.dominance_pruned");
    let s = bench("dse_cold_table2", 1, 3, || {
        let mut objective_sum = 0u64;
        for &(name, size) in &workloads {
            let gg = models::paper_kernel(name, size.max(32)).unwrap();
            let mut d = build_streaming_design(&gg).unwrap();
            objective_sum += solve(&mut d, &DseConfig::new(dev.clone())).unwrap().objective;
        }
        objective_sum
    });
    let cold_solves_per_sec = workloads.len() as f64 / s.mean.as_secs_f64();
    let candidates = metrics.get("dse.candidates") - c0;
    let pruned = metrics.get("dse.dominance_pruned") - p0;
    assert!(pruned > 0, "paper kernels must contain dominated candidates");
    let dominance_ratio = pruned as f64 / candidates.max(1) as f64;
    println!(
        "{}  [{cold_solves_per_sec:.1} cold solves/s; dominance pruned {pruned}/{candidates} \
         = {dominance_ratio:.3}]",
        s.summary()
    );

    // --- wide lattice: serial vs parallel branch-and-bound ----------------
    // A tight DSP cap puts the optimum on the resource boundary (the
    // cycle lower bound stays loose), so the exact search has real work
    // to fan out. The dominance filter is off for both sides: it prunes
    // this lattice so hard that the filtered search is too fast to need
    // parallelism — which is the layered-defense story, reported below.
    let wl_workers = 4usize;
    let g = wide_mlp(4, 72);
    let wl_dev = DeviceSpec::kv260().with_dsp_limit(128);
    let base = build_streaming_design(&g).unwrap();
    let serial_cfg = DseConfig::new(wl_dev.clone()).with_workers(1).with_dominance_filter(false);
    let (mut serial_objective, mut serial_explored) = (0u64, 0u64);
    let wl_serial = min_wall(3, || {
        let mut d = base.clone();
        let sol = solve(&mut d, &serial_cfg).unwrap();
        serial_objective = sol.objective;
        serial_explored = sol.nodes_explored;
        sol.objective
    });
    let par_cfg = DseConfig::new(wl_dev.clone())
        .with_workers(wl_workers)
        .with_dominance_filter(false)
        .with_parallel_min_volume(1);
    let mut par_objective = 0u64;
    let wl_parallel = min_wall(3, || {
        let mut d = base.clone();
        par_objective = solve(&mut d, &par_cfg).unwrap().objective;
        par_objective
    });
    assert_eq!(serial_objective, par_objective, "parallel solver diverged from serial");
    let filtered_cfg = DseConfig::new(wl_dev.clone()).with_workers(1);
    let wl_filtered = min_wall(3, || {
        let mut d = base.clone();
        solve(&mut d, &filtered_cfg).unwrap().objective
    });
    let wl_speedup = wl_serial.as_secs_f64() / wl_parallel.as_secs_f64().max(1e-9);
    println!(
        "wide_mlp4x72 @ dsp128: serial {:.1}ms ({serial_explored} nodes), \
         parallel({wl_workers}) {:.1}ms = {wl_speedup:.2}x; with dominance filter the \
         serial search takes {:.1}ms",
        wl_serial.as_secs_f64() * 1e3,
        wl_parallel.as_secs_f64() * 1e3,
        wl_filtered.as_secs_f64() * 1e3
    );

    // --- tile-grid search: serial walk vs speculative fan-out -------------
    // The BRAM-starved conv fallback: several grid candidates survive
    // the cheap prunes and need a cell DSE each before one fits.
    let gg = models::conv_relu(80, 32, 8);
    let gs_dev = DeviceSpec::kv260().with_bram_limit(4);
    let gs_serial_cfg = DseConfig::new(gs_dev.clone()).with_workers(1);
    let mut serial_cells = 0usize;
    let gs_serial = min_wall(3, || {
        serial_cells = compile_tiled(&gg, &gs_serial_cfg).unwrap().grid.n_cells();
        serial_cells
    });
    let gs_spec_cfg = DseConfig::new(gs_dev.clone()).with_workers(4);
    let mut spec_cells = 0usize;
    let gs_spec = min_wall(3, || {
        spec_cells = compile_tiled(&gg, &gs_spec_cfg).unwrap().grid.n_cells();
        spec_cells
    });
    assert_eq!(serial_cells, spec_cells, "speculative grid search diverged from serial");
    let gs_speedup = gs_serial.as_secs_f64() / gs_spec.as_secs_f64().max(1e-9);
    println!(
        "grid_search conv_relu_80 @ bram4: serial {:.1}ms, speculative(4) {:.1}ms \
         = {gs_speedup:.2}x ({serial_cells} cells committed)",
        gs_serial.as_secs_f64() * 1e3,
        gs_spec.as_secs_f64() * 1e3
    );

    // --- warm start: cold vs warm multi-size sweep ------------------------
    // The cross-problem reuse story: a sweep that revisits the same
    // kernels at several sizes shares node geometries (front cache) and
    // shapes (incumbent seeds). Cold solves every problem from scratch;
    // warm runs against a store primed by one prior pass, so the
    // measured passes are steady-state: every node front is a hit and
    // every problem starts from a validated incumbent.
    let ws_sweep: &[(&str, usize)] = &[
        ("conv_relu", 32),
        ("conv_relu", 48),
        ("cascade", 32),
        ("cascade", 48),
        ("residual", 32),
        ("residual", 48),
        ("linear", 32),
        ("feedforward", 32),
    ];
    let ws_graphs: Vec<ModelGraph> =
        ws_sweep.iter().map(|&(n, sz)| models::paper_kernel(n, sz).unwrap()).collect();
    let (mut cold_obj, mut cold_explored) = (0u64, 0u64);
    let ws_cold = min_wall(3, || {
        let (mut obj, mut exp) = (0u64, 0u64);
        for gr in &ws_graphs {
            let mut d = build_streaming_design(gr).unwrap();
            let sol = solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
            obj += sol.objective;
            exp += sol.nodes_explored;
        }
        cold_obj = obj;
        cold_explored = exp;
        obj
    });
    let warm = Arc::new(WarmStart::new());
    let warm_cfg = DseConfig::new(dev.clone()).with_warm_start(Arc::clone(&warm));
    for gr in &ws_graphs {
        // priming pass: populate fronts and record every shape's optimum
        let mut d = build_streaming_design(gr).unwrap();
        solve(&mut d, &warm_cfg).unwrap();
    }
    let h0 = metrics.get("dse.front_hits");
    let fm0 = metrics.get("dse.front_misses");
    let sd0 = metrics.get("dse.warm_seeds");
    let (mut warm_obj, mut warm_explored) = (0u64, 0u64);
    let ws_warm = min_wall(3, || {
        let (mut obj, mut exp) = (0u64, 0u64);
        for gr in &ws_graphs {
            let mut d = build_streaming_design(gr).unwrap();
            let sol = solve(&mut d, &warm_cfg).unwrap();
            obj += sol.objective;
            exp += sol.nodes_explored;
        }
        warm_obj = obj;
        warm_explored = exp;
        obj
    });
    assert_eq!(cold_obj, warm_obj, "warm-started sweep diverged from cold");
    let front_hits = metrics.get("dse.front_hits") - h0;
    let front_misses = metrics.get("dse.front_misses") - fm0;
    let warm_seeds = metrics.get("dse.warm_seeds") - sd0;
    assert!(front_hits > 0, "steady-state warm sweep must hit the front cache");
    let front_hit_rate = front_hits as f64 / (front_hits + front_misses).max(1) as f64;
    let seed_prune_ratio = 1.0 - warm_explored as f64 / cold_explored.max(1) as f64;
    let ws_speedup = ws_cold.as_secs_f64() / ws_warm.as_secs_f64().max(1e-9);
    println!(
        "warm_sweep x{}: cold {:.1}ms, warm {:.1}ms = {ws_speedup:.2}x; front hit rate \
         {front_hit_rate:.3} ({front_hits} hits), {warm_seeds} seeds pruned \
         {seed_prune_ratio:.3} of explored nodes ({cold_explored} -> {warm_explored})",
        ws_sweep.len(),
        ws_cold.as_secs_f64() * 1e3,
        ws_warm.as_secs_f64() * 1e3
    );

    let json_out = format!(
        "{{\"bench\":\"dse\",\
         \"cold\":{{\"solves_per_sec\":{cold_solves_per_sec:.1},\
         \"kernels\":{}}},\
         \"dominance\":{{\"candidates\":{candidates},\"pruned\":{pruned},\
         \"ratio\":{dominance_ratio:.4}}},\
         \"wide_lattice\":{{\"workers\":{wl_workers},\
         \"serial_ms\":{:.3},\"parallel_ms\":{:.3},\
         \"parallel_speedup\":{wl_speedup:.2},\
         \"serial_explored\":{serial_explored},\
         \"filtered_serial_ms\":{:.3}}},\
         \"grid_search\":{{\"serial_ms\":{:.3},\"speculative_ms\":{:.3},\
         \"speculative_speedup\":{gs_speedup:.2}}},\
         \"warm\":{{\"sweep_len\":{},\"cold_ms\":{:.3},\"warm_ms\":{:.3},\
         \"speedup\":{ws_speedup:.2},\"front_hits\":{front_hits},\
         \"front_hit_rate\":{front_hit_rate:.4},\"warm_seeds\":{warm_seeds},\
         \"seed_prune_ratio\":{seed_prune_ratio:.4}}}}}",
        workloads.len(),
        wl_serial.as_secs_f64() * 1e3,
        wl_parallel.as_secs_f64() * 1e3,
        wl_filtered.as_secs_f64() * 1e3,
        gs_serial.as_secs_f64() * 1e3,
        gs_spec.as_secs_f64() * 1e3,
        ws_sweep.len(),
        ws_cold.as_secs_f64() * 1e3,
        ws_warm.as_secs_f64() * 1e3,
    );
    std::fs::write("BENCH_dse.json", format!("{json_out}\n")).expect("writing BENCH_dse.json");
    println!("wrote BENCH_dse.json");

    // --- perf-regression gate (BENCH_dse_baseline.json) -------------------
    // Committed floors, deliberately conservative: fail only when a
    // gated metric drops below 80% of its baseline. The parallel-speedup
    // gates need real cores, so they only arm when >= 4 are available.
    // Re-baseline by copying numbers from a CI BENCH_dse.json artifact.
    if std::env::var_os("MING_BENCH_NO_GATE").is_some() {
        println!("perf gate: skipped (MING_BENCH_NO_GATE=1)");
    } else if let Ok(text) = std::fs::read_to_string("BENCH_dse_baseline.json") {
        let base = json::parse(&text).expect("BENCH_dse_baseline.json must parse");
        let baseline = |path: &str| -> f64 {
            let mut node = &base;
            for seg in path.split('.') {
                node = node.get(seg).unwrap_or_else(|e| panic!("baseline {path}: {e}"));
            }
            node.as_f64().unwrap_or_else(|e| panic!("baseline {path}: {e}"))
        };
        let mut gates = vec![
            ("cold.solves_per_sec", cold_solves_per_sec),
            ("dominance.ratio", dominance_ratio),
            // single-process and allocation-bound, so armed on any core
            // count: steady-state warm must stay ahead of cold
            ("warm.speedup", ws_speedup),
        ];
        if cores >= 4 {
            gates.push(("wide_lattice.parallel_speedup", wl_speedup));
            gates.push(("grid_search.speculative_speedup", gs_speedup));
        } else {
            println!("perf gate: parallelism gates skipped ({cores} cores < 4)");
        }
        let mut failed = false;
        for (path, cur) in gates {
            let floor = baseline(path) * 0.8;
            if cur < floor {
                eprintln!("perf gate FAIL {path}: {cur:.2} < floor {floor:.2} (0.8x baseline)");
                failed = true;
            } else {
                println!("perf gate ok   {path}: {cur:.2} >= floor {floor:.2}");
            }
        }
        assert!(!failed, "cold-path DSE regressed >20% vs BENCH_dse_baseline.json");
    } else {
        println!("perf gate: BENCH_dse_baseline.json not found, skipping");
    }
}
