//! Bench: regenerate the paper's Table IV (DSP-constraint sweep on the
//! single-layer 32×32 kernel: speedup, DSP used, E_DSP) and time the DSE
//! under successively tighter budgets.
//!
//! Run: `cargo bench --bench table4`

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dse::ilp::{solve, DseConfig};
use ming::dataflow::build::build_streaming_design;
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::tiling::compile_tiled;
use ming::util::bench::bench;
use ming::util::prng;
use ming::util::tables::{fnum, TextTable};

fn main() {
    let kv = DeviceSpec::kv260();
    let g = models::conv_relu(32, models::CONV_C, models::CONV_F);
    let x: Vec<i32> = prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect();
    let dv = compile_with(FrameworkKind::Vanilla, &g, &kv).unwrap();
    let base = simulate(&dv, &x, SimMode::of(dv.style)).unwrap().expect_complete().cycles;

    println!("=== Table IV (reproduction) — Vanilla baseline {base} cycles ===");
    let mut t = TextTable::new(vec!["DSP constraint", "Speedup", "DSP", "E_DSP"]);
    let mut last_speedup = f64::INFINITY;
    for cap in [1248u64, 250, 50] {
        let dev = kv.with_dsp_limit(cap);
        let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let r = estimate(&d, &dev);
        assert!(r.fits(), "design must respect the cap: {r}");
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let sp = base as f64 / rep.cycles as f64;
        assert!(sp < last_speedup, "speedup must degrade with the budget");
        last_speedup = sp;
        t.row(vec![
            cap.to_string(),
            fnum(sp, 1),
            r.dsp.to_string(),
            fnum(sp / r.dsp.max(1) as f64, 2),
        ]);
    }
    println!("{}", t.render());
    println!("shape checks passed (monotone, always feasible)\n");

    // DSE solve time under each budget
    for cap in [1248u64, 250, 50] {
        let dev = kv.with_dsp_limit(cap);
        let s = bench(&format!("dse_solve_dsp{cap}"), 2, 20, || {
            let mut d = build_streaming_design(&g).unwrap();
            solve(&mut d, &DseConfig::new(dev.clone())).unwrap()
        });
        println!("{}", s.summary());
    }

    // ---- oversized row: only MING-with-tiling places this on the KV260 --
    println!("\n=== oversized workload: vgg3 @ 512x512x256 on the KV260 ===");
    let big = models::vgg_block(512, 256, 3);
    let cfg = DseConfig::new(kv.clone());
    let mut flat = build_streaming_design(&big).unwrap();
    assert!(solve(&mut flat, &cfg).is_err(), "untiled DSE must be infeasible at 512");
    let mut t = TextTable::new(vec!["framework", "feasible", "strips", "BRAM", "DSP", "est MCycles"]);
    for fw in [FrameworkKind::Vanilla, FrameworkKind::ScaleHls, FrameworkKind::StreamHls] {
        let d = compile_with(fw, &big, &kv).unwrap();
        let r = estimate(&d, &kv);
        t.row(vec![
            fw.name().to_string(),
            if r.fits() { "yes".into() } else { "NO".to_string() },
            "—".into(),
            r.bram18k.to_string(),
            r.dsp.to_string(),
            fnum(d.overlapped_cycles_estimate() as f64 / 1e6, 2),
        ]);
    }
    let tc = compile_tiled(&big, &cfg).unwrap();
    let r = estimate(&tc.cell, &kv);
    assert!(r.bram18k <= kv.bram18k, "tiled cell must fit the stock KV260");
    t.row(vec![
        "ming (tiled)".to_string(),
        "yes".to_string(),
        tc.grid.n_cells().to_string(),
        r.bram18k.to_string(),
        r.dsp.to_string(),
        fnum(tc.estimated_cycles() as f64 / 1e6, 2),
    ]);
    println!("{}", t.render());

    let s = bench("tiling_fallback_vgg3_512", 1, 3, || compile_tiled(&big, &cfg).unwrap());
    println!("{}", s.summary());
}
