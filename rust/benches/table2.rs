//! Bench: regenerate the paper's Table II (kernel × framework: MCycles,
//! BRAM, DSP, Speedup, E_DSP) and time the compile+simulate pipeline.
//!
//! Run: `cargo bench --bench table2`

use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, SweepConfig};
use ming::resources::device::DeviceSpec;
use ming::util::bench::bench;

fn cells(dev: &DeviceSpec) -> Vec<Cell> {
    let svc = CompileService::default();
    svc.run_sweep(&SweepConfig::table2(dev.clone()))
        .iter()
        .filter_map(|r| r.as_ref().ok().map(report::cell))
        .collect()
}

fn main() {
    let dev = DeviceSpec::kv260();

    // the table itself (paper evaluation artifact)
    let c = cells(&dev);
    println!("=== Table II (reproduction) ===");
    println!("{}", report::render_table2(&c));

    // sanity assertions on the paper's shape claims
    let ming_conv32 = c
        .iter()
        .find(|x| x.kernel == "conv_relu" && x.size == 32 && x.framework.name() == "ming")
        .unwrap();
    let sp = report::speedup(&c, ming_conv32).unwrap();
    assert!(sp > 100.0, "single-layer MING speedup must be in the hundreds: {sp}");
    assert!(ming_conv32.fits);
    println!("shape checks passed (MING conv32 speedup {sp:.0}x)\n");

    // timing of the full sweep (32 designs compiled + simulated)
    let s = bench("table2_full_sweep", 1, 5, || cells(&dev));
    println!("{}", s.summary());
}
