//! Bench: regenerate the paper's Fig. 3 series (single-layer BRAM
//! utilization vs input size — StreamHLS grows near-linearly with the
//! input area, MING stays constant).
//!
//! Run: `cargo bench --bench fig3`

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::util::bench::bench;
use ming::util::tables::TextTable;

const SIZES: [usize; 7] = [32, 64, 96, 128, 160, 192, 224];

fn series(fw: FrameworkKind, dev: &DeviceSpec) -> Vec<u64> {
    SIZES
        .iter()
        .map(|&n| {
            let g = models::conv_relu(n, models::CONV_C, models::CONV_F);
            let d = compile_with(fw, &g, dev).unwrap();
            estimate(&d, dev).bram18k
        })
        .collect()
}

fn main() {
    let dev = DeviceSpec::kv260();
    let sh = series(FrameworkKind::StreamHls, &dev);
    let vg = series(FrameworkKind::Vanilla, &dev);
    let mg = series(FrameworkKind::Ming, &dev);

    println!("=== Fig. 3 (reproduction): BRAM18K vs input size ===");
    let mut t = TextTable::new(vec!["input", "vanilla", "streamhls", "ming", "KV260 cap"]);
    for (i, &n) in SIZES.iter().enumerate() {
        t.row(vec![
            format!("{n}x{n}"),
            vg[i].to_string(),
            sh[i].to_string(),
            mg[i].to_string(),
            dev.bram18k.to_string(),
        ]);
    }
    println!("{}", t.render());

    // shape claims: StreamHLS strictly increasing & over budget at 224;
    // MING constant and under budget everywhere.
    assert!(sh.windows(2).all(|w| w[0] < w[1]), "StreamHLS BRAM must grow: {sh:?}");
    assert!(sh.last().unwrap() > &dev.bram18k, "StreamHLS must exceed the KV260 at 224");
    assert!(mg.windows(2).all(|w| w[0] == w[1]), "MING BRAM must be constant: {mg:?}");
    assert!(mg[0] < dev.bram18k);
    // near-linear growth in input area: ratio of successive increments ~const
    let r_end = sh[6] as f64 / sh[0] as f64;
    let area = (224.0f64 / 32.0).powi(2);
    assert!(
        r_end > 0.5 * area && r_end < 2.0 * area,
        "StreamHLS growth should track input area: {r_end} vs {area}"
    );
    println!("shape checks passed (linear growth vs constant 16)\n");

    let s = bench("fig3_series_streamhls", 1, 10, || series(FrameworkKind::StreamHls, &dev));
    println!("{}", s.summary());
    let s = bench("fig3_series_ming", 1, 5, || series(FrameworkKind::Ming, &dev));
    println!("{}", s.summary());
}
