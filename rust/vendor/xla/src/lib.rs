//! Graceful stub of the `xla` crate (PJRT bindings) for environments
//! where the native `xla_extension` runtime is unavailable.
//!
//! The API surface mirrors exactly what `ming::runtime::pjrt` uses. The
//! CPU client constructs (so runtime plumbing can be exercised), but
//! loading or compiling HLO returns a descriptive error — callers treat
//! that the same way as missing artifacts and skip golden verification.
//! Swap this path dependency for the real `xla` crate to run the
//! JAX/Pallas golden models through PJRT.

use std::fmt;

/// Stub error type (implements `std::error::Error` so callers can wrap
/// it with `anyhow::Context`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "xla stub: {what} unavailable (vendored offline stub; \
             link the real xla crate for PJRT execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. Only the CPU platform exists in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("HLO compilation"))
    }
}

/// Parsed HLO module (never actually constructed by the stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("HLO text parsing ({path})")))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled executable (never produced by the stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execution"))
    }
}

/// A device buffer holding one execution result.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("device-to-host transfer"))
    }
}

/// Element types a [`Literal`] can yield. The stub only carries i32 (the
/// ming interchange convention).
pub trait LiteralElem: Copy {
    fn from_i32(v: i32) -> Self;
}

impl LiteralElem for i32 {
    fn from_i32(v: i32) -> i32 {
        v
    }
}

/// Host literal: flat i32 data plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<i32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(values: &[i32]) -> Literal {
        Literal { data: values.to_vec(), dims: vec![values.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements do not fit shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_i32(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_constructs() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
    }

    #[test]
    fn hlo_loading_reports_stub() {
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(Literal::vec1(&[1]).reshape(&[7]).is_err());
    }
}
