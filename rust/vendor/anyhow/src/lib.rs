//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! This build environment is fully offline, so the real crates.io
//! dependency is unavailable; this crate implements exactly the API
//! surface the `ming` workspace uses:
//!
//! * [`Error`] — a message with a context chain; `{e}` prints the
//!   outermost message, `{e:#}` the whole chain colon-joined (matching
//!   anyhow's alternate formatting).
//! * [`Result<T>`] with `?`-conversion from any `std::error::Error`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * The [`Context`] extension trait for `Result` and `Option`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with an overridable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error value: the outermost message first, then successively deeper
/// causes/contexts.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (the `anyhow::Error::context`
    /// inherent method).
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The deepest (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            // Error::msg directly: stringify! may contain brace characters
            // that format! would misread as placeholders.
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn chain_formatting() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.root_cause(), "inner 42");
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            let parsed: i32 = "17".parse()?;
            Ok(parsed + x)
        }
        assert_eq!(f(1).unwrap(), 18);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn std_error_conversion_keeps_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        let e: Error = io.into();
        assert!(e.to_string().contains("disk on fire"));
    }
}
