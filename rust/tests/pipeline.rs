//! Integration: the full compile pipeline (IR → analysis → dataflow →
//! DSE → resources → codegen → simulation) across kernels, frameworks,
//! devices and sizes — everything short of the PJRT golden model (see
//! `golden_e2e.rs`).

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::codegen::{emit_design, emit_testbench};
use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, SweepConfig};
use ming::dataflow::validate::{check_diamond_depths, validate_design};
use ming::ir::builder::models;
use ming::ir::json::import_model;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::util::prng;

fn det_input(g: &ming::ir::graph::ModelGraph) -> Vec<i32> {
    prng::det_tensor(prng::SEED_INPUT, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect()
}

/// Every (paper kernel × framework) compiles, validates structurally, and
/// simulates to completion with identical functional output.
#[test]
fn all_kernels_all_frameworks_agree_functionally() {
    let dev = DeviceSpec::kv260();
    for (kernel, size) in [
        ("conv_relu", 32usize),
        ("cascade", 32),
        ("residual", 32),
        ("linear", 0),
        ("feedforward", 0),
    ] {
        let g = models::paper_kernel(kernel, size).unwrap();
        let x = det_input(&g);
        let mut outputs: Vec<Vec<i32>> = Vec::new();
        for fw in FrameworkKind::all() {
            let d = compile_with(fw, &g, &dev).unwrap();
            validate_design(&d).unwrap_or_else(|e| panic!("{kernel}/{}: {e}", fw.name()));
            let rep = simulate(&d, &x, SimMode::of(d.style))
                .unwrap_or_else(|e| panic!("{kernel}/{}: {e}", fw.name()));
            assert!(
                rep.deadlock.is_none(),
                "{kernel}/{} deadlocked: {:?}",
                kernel,
                rep.deadlock
            );
            outputs.push(rep.output);
        }
        for w in outputs.windows(2) {
            assert_eq!(w[0], w[1], "{kernel}: frameworks disagree functionally");
        }
    }
}

/// The paper's central feasibility claim: at 224×224 only MING fits the
/// KV260; at 32×32 everything but StreamHLS-on-linears fits.
#[test]
fn feasibility_matrix_matches_paper() {
    let dev = DeviceSpec::kv260();
    for (kernel, size, fw, expect_fit) in [
        ("conv_relu", 224, FrameworkKind::Vanilla, false),
        ("conv_relu", 224, FrameworkKind::StreamHls, false),
        ("conv_relu", 224, FrameworkKind::Ming, true),
        ("cascade", 224, FrameworkKind::Ming, true),
        ("residual", 224, FrameworkKind::Ming, true),
        ("linear", 0, FrameworkKind::StreamHls, false),
        ("linear", 0, FrameworkKind::Ming, true),
        ("feedforward", 0, FrameworkKind::StreamHls, false),
        ("feedforward", 0, FrameworkKind::Ming, true),
    ] {
        let g = models::paper_kernel(kernel, size).unwrap();
        let d = compile_with(fw, &g, &dev).unwrap();
        let r = estimate(&d, &dev);
        assert_eq!(
            r.fits(),
            expect_fit,
            "{kernel}@{size}/{}: expected fits={expect_fit}, got {r}",
            fw.name()
        );
    }
}

/// Speedup ordering across the whole Table II sweep:
/// MING > StreamHLS > Vanilla ≥ ScaleHLS on every conv workload.
#[test]
fn speedup_ordering_holds_per_workload() {
    let svc = CompileService::default();
    let cells: Vec<Cell> = svc
        .run_sweep(&SweepConfig::table2(DeviceSpec::kv260()))
        .iter()
        .filter_map(|r| r.as_ref().ok().map(report::cell))
        .collect();
    for (kernel, size) in [("conv_relu", 32usize), ("cascade", 32), ("residual", 32)] {
        let sp = |fw: FrameworkKind| {
            let c = cells
                .iter()
                .find(|c| c.kernel == kernel && c.size == size && c.framework == fw)
                .unwrap();
            report::speedup(&cells, c).unwrap()
        };
        assert!(sp(FrameworkKind::Ming) > sp(FrameworkKind::StreamHls), "{kernel}");
        assert!(sp(FrameworkKind::StreamHls) > 1.0, "{kernel}");
        assert!(sp(FrameworkKind::ScaleHls) <= 1.05, "{kernel}: ScaleHLS must not beat Vanilla");
        assert!(sp(FrameworkKind::Ming) > 100.0, "{kernel}: MING speedup in the hundreds");
    }
}

/// MING's resource usage is invariant to input size (paper §V-B: "BRAM
/// and DSP remain consistent regardless of input size").
#[test]
fn ming_resources_invariant_to_input_size() {
    let dev = DeviceSpec::kv260();
    let mut seen = Vec::new();
    for n in [32usize, 64, 128, 224] {
        let g = models::conv_relu(n, models::CONV_C, models::CONV_F);
        let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let r = estimate(&d, &dev);
        seen.push((r.bram18k, r.dsp));
    }
    assert!(seen.windows(2).all(|w| w[0] == w[1]), "resources vary with size: {seen:?}");
}

/// Codegen round-trips: emitted C++ contains every node, every channel's
/// STREAM pragma with the DSE-chosen depth, and the testbench embeds the
/// simulator's expected outputs.
#[test]
fn codegen_consistent_with_design_and_sim() {
    let dev = DeviceSpec::kv260();
    let g = models::residual(32, models::CONV_C, models::CONV_F);
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    let cpp = emit_design(&d);
    for n in &d.nodes {
        assert!(cpp.contains(&format!("static void {}_proc(", n.name)), "missing {}", n.name);
    }
    for c in &d.channels {
        assert!(
            cpp.contains(&format!("variable={} depth={}", c.name, c.depth)),
            "missing STREAM for {}",
            c.name
        );
    }
    let x = det_input(&g);
    let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    let tb = emit_testbench(&d, &x, Some(&rep.output));
    assert!(tb.contains("tb_expected"));
    assert!(tb.contains(&format!("{}_top(", g.name)));
}

/// JSON front-end → full pipeline: a three-layer CNN head imported from
/// JSON compiles, fits, and simulates deterministically twice.
#[test]
fn json_front_end_full_pipeline() {
    let src = r#"{
        "name": "edge_classifier",
        "input": {"shape": [24, 24, 4], "dtype": "i8"},
        "layers": [
          {"op": "conv2d", "filters": 8, "kernel": 3, "seed": 31},
          {"op": "conv2d", "filters": 4, "kernel": 3, "seed": 32}
        ]
      }"#;
    let g = import_model(src).unwrap();
    let dev = DeviceSpec::kv260();
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    assert!(estimate(&d, &dev).fits());
    let x = det_input(&g);
    let a = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    let b = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    assert_eq!(a.output, b.output);
    assert_eq!(a.cycles, b.cycles, "simulation must be deterministic");
}

/// Diamond FIFO sizing works for deeper diamonds than the paper's
/// residual block (two stacked residuals).
#[test]
fn stacked_residuals_deadlock_free() {
    use ming::ir::builder::GraphBuilder;
    use ming::ir::types::DType;
    let mut b = GraphBuilder::new("double_residual");
    let x = b.input("x", vec![24, 24, 8], DType::I8);
    let w1 = b.det_weight("w1", vec![8, 3, 3, 8], 61);
    let w2 = b.det_weight("w2", vec![8, 3, 3, 8], 62);
    let a0 = b.conv2d("conv0", x, w1, 1, 1);
    let t0 = b.requant("req0", a0);
    let s0 = b.add_sat("add0", x, t0);
    let a1 = b.conv2d("conv1", s0, w2, 1, 1);
    let t1 = b.requant("req1", a1);
    let s1 = b.add_sat("add1", s0, t1);
    let y = b.relu("relu_out", s1);
    b.mark_output(y);
    let g = b.finish();
    g.validate().unwrap();

    let dev = DeviceSpec::kv260();
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    assert!(check_diamond_depths(&d).is_empty(), "{:?}", check_diamond_depths(&d));
    let x = det_input(&g);
    let rep = simulate(&d, &x, SimMode::Dataflow).unwrap();
    assert!(rep.deadlock.is_none(), "{:?}", rep.deadlock);
}

/// Device sweep: MING fits everywhere; StreamHLS busts the KV260 at
/// 224x224 and — per the paper's §V-B remark ("even on FPGAs for the
/// cloud this issue persists when scaling up") — still struggles on the
/// cloud-grade U250 at that size, while a mid-size 96x96 fits there.
#[test]
fn device_monotonicity() {
    let g224 = models::cascade(224, models::CONV_C, models::CONV_F);
    for dev in [DeviceSpec::kv260(), DeviceSpec::zcu104(), DeviceSpec::u250()] {
        let dm = compile_with(FrameworkKind::Ming, &g224, &dev).unwrap();
        assert!(estimate(&dm, &dev).fits(), "MING must fit {}", dev.name);
    }
    let kv = DeviceSpec::kv260();
    let u250 = DeviceSpec::u250();
    let dsh = compile_with(FrameworkKind::StreamHls, &g224, &kv).unwrap();
    assert!(!estimate(&dsh, &kv).fits(), "StreamHLS busts the KV260 at 224");
    // mid-size point: fails the edge part, fits the cloud part
    let g96 = models::cascade(96, models::CONV_C, models::CONV_F);
    let d96 = compile_with(FrameworkKind::StreamHls, &g96, &kv).unwrap();
    assert!(!estimate(&d96, &kv).fits(), "StreamHLS 96x96 should bust the KV260");
    let d96u = compile_with(FrameworkKind::StreamHls, &g96, &u250).unwrap();
    assert!(estimate(&d96u, &u250).fits(), "StreamHLS 96x96 fits the U250");
}
