//! Failure injection: malformed IR, undersized devices, corrupted
//! designs, bad front-end input — every layer must fail loudly and
//! informatively, never silently mis-compile.

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dataflow::build::build_streaming_design;
use ming::dataflow::validate::validate_design;
use ming::dse::ilp::{solve, DseConfig};
use ming::ir::affine::{AffineExpr, AffineMap};
use ming::ir::builder::{models, GraphBuilder};
use ming::ir::generic::{GenericOp, IterType, Payload};
use ming::ir::graph::TensorId;
use ming::ir::json::import_model;
use ming::ir::types::DType;
use ming::resources::device::DeviceSpec;
use ming::sim::{simulate, SimMode};

#[test]
fn graph_with_shape_mismatch_rejected() {
    let mut b = GraphBuilder::new("bad");
    let x = b.input("x", vec![8, 8, 4], DType::I8);
    // weight channel count disagrees with input
    let w = b.det_weight("w", vec![4, 3, 3, 2], 1);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        b.conv2d("conv0", x, w, 1, 1)
    }));
    assert!(result.is_err(), "channel mismatch must be rejected at build time");
}

#[test]
fn op_reading_out_of_bounds_rejected() {
    // Hand-craft an op whose indexing map walks past the tensor bounds.
    let mut b = GraphBuilder::new("oob");
    let x = b.input("x", vec![8, 8], DType::I8);
    let mut g = b.finish();
    let out = g.add_tensor(
        "y",
        ming::ir::types::TensorType::new(vec![8, 8], DType::I32),
        ming::ir::graph::TensorKind::Output,
        None,
    );
    g.ops.push(GenericOp {
        name: "bad".into(),
        inputs: vec![x],
        output: out,
        indexing_maps: vec![
            // reads (d0 * 2, d1): rows 0..14 of an 8-row tensor
            AffineMap::new(2, vec![AffineExpr::scaled(0, 2), AffineExpr::dim(1)]),
            AffineMap::identity(2),
        ],
        iter_types: vec![IterType::Parallel; 2],
        dims: vec![8, 8],
        payload: Payload::Copy,
        pad: 0,
    });
    let err = g.validate().unwrap_err().to_string();
    assert!(err.contains("outside"), "got: {err}");
}

#[test]
fn dangling_tensor_reference_rejected() {
    let mut b = GraphBuilder::new("dangling");
    let x = b.input("x", vec![4, 4, 2], DType::I8);
    let w = b.det_weight("w", vec![2, 3, 3, 2], 1);
    let y = b.conv2d("conv0", x, w, 1, 1);
    b.mark_output(y);
    let mut g = b.finish();
    g.ops[0].inputs[0] = TensorId(999);
    assert!(g.validate().is_err());
}

#[test]
fn dse_infeasible_on_starved_devices() {
    let g = models::conv_relu(32, 8, 8);
    // zero DSPs: even the scalar design needs one
    let mut d = build_streaming_design(&g).unwrap();
    let err = solve(&mut d, &DseConfig::new(DeviceSpec::kv260().with_dsp_limit(0)))
        .unwrap_err()
        .to_string();
    assert!(err.contains("infeasible") || err.contains("no feasible"), "got: {err}");
    // near-zero BRAM: the line buffers alone exceed it
    let mut d = build_streaming_design(&g).unwrap();
    assert!(solve(&mut d, &DseConfig::new(DeviceSpec::kv260().with_bram_limit(1))).is_err());
}

#[test]
fn corrupted_design_fails_validation_not_simulation() {
    let g = models::cascade(16, 8, 8);
    let mut d = build_streaming_design(&g).unwrap();
    // cut a channel loose
    d.nodes[1].in_channels.clear();
    assert!(validate_design(&d).is_err());
}

#[test]
fn undersized_diamond_fifo_reports_deadlock_with_blame() {
    let g = models::residual(32, 8, 8);
    let d = build_streaming_design(&g).unwrap(); // no FIFO sizing pass
    let x: Vec<i32> = vec![1; g.inputs()[0].ty.numel()];
    let rep = simulate(&d, &x, SimMode::Dataflow).unwrap();
    let blocked = rep.deadlock.expect("must deadlock");
    // the report must name the blocked node and the starving channel
    assert!(
        blocked.iter().any(|b| b.contains("add0")),
        "deadlock report should blame the join: {blocked:?}"
    );
}

#[test]
fn simulate_rejects_wrong_input_shape() {
    let g = models::linear();
    let d = build_streaming_design(&g).unwrap();
    assert!(simulate(&d, &[1, 2, 3], SimMode::Dataflow).is_err());
}

#[test]
fn front_end_rejects_malformed_json() {
    for src in [
        "{",                                         // truncated
        r#"{"name": 3, "input": {}, "layers": []}"#, // wrong types
        r#"{"name": "x", "input": {"shape": [8, 8]}, "layers": [{"op": "conv2d", "filters": 4}]}"#, // conv on rank-2
        r#"{"name": "x", "input": {"shape": [8, 8, 2], "dtype": "f64"}, "layers": []}"#, // bad dtype
    ] {
        assert!(import_model(src).is_err(), "should reject: {src}");
    }
}

#[test]
fn compile_service_isolates_bad_jobs() {
    use ming::coordinator::service::{CompileService, SweepConfig};
    let cfg = SweepConfig {
        workloads: vec![("conv_relu".into(), 16), ("no_such_kernel".into(), 16)],
        frameworks: vec![FrameworkKind::Ming],
        device: DeviceSpec::kv260(),
        estimate_only: true,
    };
    let results = CompileService::default().run_sweep(&cfg);
    assert_eq!(results.len(), 2);
    assert!(results[0].is_ok());
    assert!(results[1].is_err(), "bad kernel must fail in isolation");
}

#[test]
fn streamhls_linear_flagged_infeasible_but_still_analyzable() {
    // The paper marks StreamHLS's Linear design as exceeding resources;
    // our pipeline must still produce the design + report (not crash).
    let g = models::linear();
    let dev = DeviceSpec::kv260();
    let d = compile_with(FrameworkKind::StreamHls, &g, &dev).unwrap();
    let r = ming::resources::estimate(&d, &dev);
    assert!(!r.fits());
    assert!(r.violations().iter().any(|v| v.starts_with("DSP")));
}
