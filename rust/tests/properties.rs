//! Property-based integration tests over randomly generated models:
//! graph invariants, analysis invariants, DSE constraint satisfaction,
//! simulator/structural agreement — driven by the in-repo property
//! harness (`ming::util::prop`).

use ming::analysis::classify::{classify, KernelClass};
use ming::analysis::iters::classify_iterators;
use ming::analysis::shapes::node_geometry;
use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::dataflow::build::build_streaming_design;
use ming::dataflow::validate::{check_diamond_depths, validate_design};
use ming::dse::ilp::{solve, DseConfig};
use ming::ir::builder::GraphBuilder;
use ming::ir::graph::ModelGraph;
use ming::ir::types::DType;
use ming::resources::device::DeviceSpec;
use ming::resources::estimate;
use ming::sim::{simulate, SimMode};
use ming::util::prng::XorShift;
use ming::util::prop::{forall, Gen};

/// Generate a random small CNN: 1-3 conv layers (+ optional residual
/// skip when shapes allow) or 1-3 linear layers.
fn random_graph(g: &mut Gen) -> ModelGraph {
    let rng = &mut g.rng;
    let mut b = GraphBuilder::new(format!("rand{}", g.case));
    if rng.chance(1, 3) {
        // MLP
        let m = 8 << rng.below(3); // 8/16/32
        let mut k = 4 << rng.below(3) as usize;
        let x = b.input("x", vec![m as usize, k], DType::I8);
        let mut cur = x;
        let layers = 1 + rng.below(3);
        for li in 0..layers {
            let n = 4 << rng.below(3) as usize;
            let w = b.det_weight(&format!("w{li}"), vec![k, n], 1000 + li);
            let acc = b.linear(&format!("mm{li}"), cur, w);
            cur = b.relu_requant(&format!("rr{li}"), acc);
            k = n;
        }
        b.mark_output(cur);
    } else {
        // CNN
        let n = 8 + 2 * rng.below(9) as usize; // 8..24
        let c = 1 << rng.below(3) as usize; // 1/2/4
        let x = b.input("x", vec![n, n, c], DType::I8);
        let mut cur = x;
        let mut cc = c;
        let layers = 1 + rng.below(3);
        let skip_ok = layers >= 2 && rng.chance(1, 2);
        let mut first_out = None;
        for li in 0..layers {
            let f = if skip_ok { cc } else { 1 << rng.below(3) as usize };
            let w = b.det_weight(&format!("w{li}"), vec![f, 3, 3, cc], 2000 + li);
            let acc = b.conv2d(&format!("conv{li}"), cur, w, 1, 1);
            cur = if li + 1 == layers && skip_ok {
                b.requant(&format!("req{li}"), acc)
            } else {
                b.relu_requant(&format!("rr{li}"), acc)
            };
            if li == 0 {
                first_out = Some(cur);
            }
            cc = f;
        }
        if skip_ok {
            let s = b.add_sat("skip_add", first_out.unwrap(), cur);
            cur = b.relu("relu_out", s);
        }
        b.mark_output(cur);
    }
    let g = b.finish();
    g.validate().expect("generator must produce valid graphs");
    g
}

fn det_input(g: &ModelGraph, seed: u64) -> Vec<i32> {
    ming::util::prng::det_tensor(seed, g.inputs()[0].ty.numel())
        .iter()
        .map(|&v| v as i32)
        .collect()
}

#[test]
fn prop_algorithm2_sets_partition_dims() {
    // P, R disjoint; W disjoint from P; every dim of every op appears in
    // P ∪ R ∪ O ∪ W (CNN ops leave no dim unclassified).
    forall("algo2 partitions", 60, random_graph, |g| {
        g.ops.iter().all(|op| {
            let s = classify_iterators(op);
            let all: std::collections::BTreeSet<usize> =
                s.p.iter().chain(&s.r).chain(&s.o).chain(&s.w).copied().collect();
            s.p.is_disjoint(&s.r)
                && s.p.is_disjoint(&s.w)
                && all.len() == op.dims.len()
        })
    });
}

#[test]
fn prop_classification_consistent_with_structure() {
    forall("class consistency", 60, random_graph, |g| {
        g.ops.iter().all(|op| match classify(op) {
            KernelClass::SlidingWindow(sw) => {
                op.has_reduction() && sw.stride > 0 && sw.dilation > 0
            }
            KernelClass::RegularReduction => op.has_reduction(),
            KernelClass::PureParallel => !op.has_reduction(),
        })
    });
}

#[test]
fn prop_geometry_token_conservation() {
    // Output token count × token length == output tensor numel; ditto for
    // each activation input.
    forall("token conservation", 60, random_graph, |g| {
        g.ops.iter().all(|op| {
            let geo = node_geometry(g, op).unwrap();
            let out_numel = g.tensor(op.output).ty.numel() as u64;
            geo.out_tokens * geo.out_token_len as u64 == out_numel
        })
    });
}

#[test]
fn prop_designs_validate_and_dse_respects_constraints() {
    let dev = DeviceSpec::kv260();
    forall("dse constraints", 40, random_graph, |g| {
        let mut d = build_streaming_design(g).unwrap();
        validate_design(&d).unwrap();
        solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        let r = estimate(&d, &dev);
        // DSE must produce deadlock-free, feasible designs
        r.fits() && check_diamond_depths(&d).is_empty()
    });
}

#[test]
fn prop_unroll_divides_trip_counts() {
    let dev = DeviceSpec::kv260();
    forall("unroll | trip", 40, random_graph, |g| {
        let mut d = build_streaming_design(g).unwrap();
        solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        d.nodes.iter().all(|n| {
            let op = &d.graph.ops[n.op_index];
            let par = n.geo.out_token_len as u64;
            let red = op.reduction_space().max(1);
            par % n.timing.unroll_par == 0 && red % n.timing.unroll_red == 0
        })
    });
}

#[test]
fn prop_solver_bram_is_design_bram() {
    // The unified-resource-model invariant on random models: the ILP's
    // reported usage equals the emitted design's accounting, exactly —
    // estimate and implementation can never disagree.
    let dev = DeviceSpec::kv260();
    forall("bram_used == design_bram", 40, random_graph, |g| {
        let mut d = build_streaming_design(g).unwrap();
        let sol = solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        sol.bram_used == ming::resources::bram::design_bram(&d)
            && sol.dsp_used == ming::resources::dsp::design_dsp(&d)
            && sol.resources.bram() == sol.bram_used
    });
}

#[test]
fn prop_paper_kernels_solver_bram_is_design_bram_on_kv260() {
    // The same invariant pinned on every paper kernel (the acceptance
    // bar of the unified resource model), plus the tiled oversized
    // showcase: the strip solution's bram_used is the strip design_bram.
    use ming::ir::builder::models;
    let dev = DeviceSpec::kv260();
    for (name, size) in models::table2_workloads() {
        let g = models::paper_kernel(name, size.max(32)).unwrap();
        let mut d = build_streaming_design(&g).unwrap();
        let sol = solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        assert_eq!(
            sol.bram_used,
            ming::resources::bram::design_bram(&d),
            "{name}@{size}: solver and design disagree"
        );
    }
    // tiled vgg3@512 (estimate-only scale): same invariant on the cell
    let g = models::vgg_block(512, 256, 3);
    let tc = ming::tiling::compile_tiled(&g, &DseConfig::new(dev.clone())).unwrap();
    assert_eq!(
        tc.solution.bram_used,
        ming::resources::bram::design_bram(&tc.cell),
        "tiled cell: solver and design disagree"
    );
    assert!(tc.solution.bram_used <= dev.bram18k);
}

#[test]
fn prop_modeled_vector_monotone_in_weight_bits() {
    // Adding weight bits never decreases the modeled resource vector:
    // grow a linear layer's weight tensor and compare the node vectors
    // under identical timings.
    use ming::ir::builder::GraphBuilder as GB;
    use ming::resources::model::ResourceModel;
    forall(
        "weight-bit monotonicity",
        25,
        |g| {
            let k = 8 << g.rng.below(3) as usize; // 8/16/32
            let n1 = 4 << g.rng.below(3) as usize;
            let n2 = n1 * (1 + g.rng.below(4) as usize); // n2 >= n1
            (k, n1, n2)
        },
        |&(k, n1, n2)| {
            let build = |n: usize| {
                let mut b = GB::new(format!("mono{n}"));
                let x = b.input("x", vec![16, k], DType::I8);
                let w = b.det_weight("w", vec![k, n], 1);
                let acc = b.linear("mm0", x, w);
                let y = b.relu_requant("rr0", acc);
                b.mark_output(y);
                let g = b.finish();
                build_streaming_design(&g).unwrap()
            };
            let d1 = build(n1);
            let d2 = build(n2);
            let (m1, m2) = (ResourceModel::new(&d1), ResourceModel::new(&d2));
            // same timing in both designs (scalar defaults)
            let t = d1.nodes[0].timing;
            let (v1, v2) = (m1.node_vec(0, &t), m2.node_vec(0, &t));
            v1.weight_bram <= v2.weight_bram && v1.bram() <= v2.bram()
        },
    );
}

#[test]
fn prop_arena_engine_matches_naive_reference_exactly() {
    // The arena data plane is pinned to the retained naive reference
    // engine on random graphs: identical outputs, identical cycle
    // counts, identical FIFO high-water marks, identical traces — in
    // both scheduling modes, scalar and DSE-tuned. Two independent
    // implementations of the timing contract must agree bit-for-bit
    // before either is trusted.
    use ming::sim::naive::simulate_naive;
    let dev = DeviceSpec::kv260();
    forall("arena == naive", 25, random_graph, |g| {
        let x = det_input(g, 13);
        for tuned in [false, true] {
            let mut d = build_streaming_design(g).unwrap();
            if tuned {
                solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
            }
            let modes: &[SimMode] = if tuned {
                &[SimMode::Dataflow, SimMode::Sequential]
            } else {
                // scalar designs have unsized FIFOs: Sequential only
                // (Dataflow may legitimately deadlock on diamonds, which
                // the dedicated deadlock-agreement test covers)
                &[SimMode::Sequential]
            };
            for &mode in modes {
                let a = simulate(&d, &x, mode).unwrap();
                let n = simulate_naive(&d, &x, mode).unwrap();
                assert_eq!(a.output, n.output, "{} {mode:?}: output", g.name);
                assert_eq!(a.cycles, n.cycles, "{} {mode:?}: cycles", g.name);
                assert_eq!(
                    a.fifo_high_water, n.fifo_high_water,
                    "{} {mode:?}: high water",
                    g.name
                );
                assert_eq!(a.total_firings, n.total_firings, "{}", g.name);
                assert_eq!(a.token_ops, n.token_ops, "{}", g.name);
                assert_eq!(a.deadlock, n.deadlock, "{}", g.name);
                for (ta, tn) in a.traces.iter().zip(&n.traces) {
                    assert_eq!(
                        (ta.firings, ta.first_fire, ta.last_fire, ta.complete),
                        (tn.firings, tn.first_fire, tn.last_fire, tn.complete),
                        "{}/{}: trace",
                        g.name,
                        ta.name
                    );
                    assert_eq!(ta.stall_in, tn.stall_in, "{}/{}", g.name, ta.name);
                    assert_eq!(ta.stall_out, tn.stall_out, "{}/{}", g.name, ta.name);
                }
            }
        }
        true
    });
}

#[test]
fn prop_parallel_tiled_simulation_matches_serial() {
    // Random pooled stride chains through the scheduler fan-out: for
    // every buildable grid, the parallel tiled simulation is identical
    // to the serial one — stitched output, total cycles, per-cell
    // cycles — at several worker counts.
    use ming::coordinator::Scheduler;
    use ming::tiling::{compile_tiled_fixed, simulate_tiled, simulate_tiled_parallel};
    let dev = DeviceSpec::kv260();
    forall("parallel tiled == serial", 8, random_stride_chain, |g| {
        let x = det_input(g, 23);
        let mut checked = 0;
        for (rows, cols) in candidate_grids(g) {
            let Ok(tc) = compile_tiled_fixed(g, &DseConfig::new(dev.clone()), rows, cols)
            else {
                continue;
            };
            let serial = simulate_tiled(&tc, &x).unwrap();
            for workers in [2usize, 5] {
                let par = simulate_tiled_parallel(&tc, &x, &Scheduler::new(workers)).unwrap();
                if par.output != serial.output
                    || par.cycles != serial.cycles
                    || par.tile_cycles != serial.tile_cycles
                    || par.total_firings != serial.total_firings
                {
                    return false;
                }
            }
            checked += 1;
        }
        checked > 0
    });
}

#[test]
fn prop_simulation_agrees_across_modes_and_unrolls() {
    // Functional output must be invariant to: scheduling mode, and the
    // DSE's unroll decisions. Cycle counts must only improve.
    let dev = DeviceSpec::kv260();
    forall("sim invariance", 25, random_graph, |g| {
        let x = det_input(g, 7);
        let base = build_streaming_design(g).unwrap();
        let seq = simulate(&base, &x, SimMode::Sequential).unwrap();
        assert!(seq.deadlock.is_none());
        let mut tuned = build_streaming_design(g).unwrap();
        solve(&mut tuned, &DseConfig::new(dev.clone())).unwrap();
        let df = simulate(&tuned, &x, SimMode::Dataflow).unwrap();
        assert!(df.deadlock.is_none(), "{:?}", df.deadlock);
        seq.output == df.output && df.cycles <= seq.cycles
    });
}

#[test]
fn prop_all_frameworks_functionally_identical() {
    let dev = DeviceSpec::kv260();
    forall("framework agreement", 15, random_graph, |g| {
        let x = det_input(g, 11);
        let mut outs = Vec::new();
        for fw in FrameworkKind::all() {
            let d = compile_with(fw, g, &dev).unwrap();
            let rep = simulate(&d, &x, SimMode::of(d.style)).unwrap();
            assert!(rep.deadlock.is_none(), "{} deadlock {:?}", fw.name(), rep.deadlock);
            outs.push(rep.output);
        }
        outs.windows(2).all(|w| w[0] == w[1])
    });
}

/// Generate a random grid-tilable stride/kernel chain: 1-3 same-padded
/// 3x3 conv stages interleaved with up to two 2x2 stride-2 max-pools on
/// a power-of-two input (so every pool divides exactly).
fn random_stride_chain(g: &mut Gen) -> ModelGraph {
    let rng = &mut g.rng;
    // 32 keeps simulation cheap while guaranteeing that even the deepest
    // chain's halo (two pools + three convs -> up to ~20 input columns)
    // leaves at least one buildable grid (1x4 over the 8-wide output)
    let n = 32usize;
    let c = 1usize << rng.below(3); // 1/2/4
    let mut b = GraphBuilder::new(format!("chain{}", g.case));
    let x = b.input("x", vec![n, n, c], DType::I8);
    let mut cur = x;
    let mut cc = c;
    let mut extent = n;
    let mut pools = 0;
    let stages = 1 + rng.below(3);
    for li in 0..stages {
        let f = 1usize << rng.below(3);
        let w = b.det_weight(&format!("w{li}"), vec![f, 3, 3, cc], 3000 + li);
        let acc = b.conv2d(&format!("conv{li}"), cur, w, 1, 1);
        cur = b.relu_requant(&format!("rr{li}"), acc);
        cc = f;
        if pools < 2 && extent >= 8 && rng.chance(1, 2) {
            cur = b.maxpool2d(&format!("pool{li}"), cur, 2, 2);
            extent /= 2;
            pools += 1;
        }
    }
    b.mark_output(cur);
    let g = b.finish();
    g.validate().expect("generator must produce valid graphs");
    g
}

/// Candidate grids for a chain's output extents: small divisors first.
fn candidate_grids(g: &ModelGraph) -> Vec<(usize, usize)> {
    let out = &g.outputs()[0].ty.shape;
    let (h, w) = (out[0], out[1]);
    [(1usize, 2usize), (2, 1), (2, 2), (1, 4), (4, 4)]
        .into_iter()
        .filter(|&(r, c)| h % r == 0 && w % c == 0)
        .collect()
}

#[test]
fn prop_grid_halos_cover_every_dependency_cone() {
    // For every cell of every buildable grid over random stride/kernel
    // chains: each kept output's dependency cone either lies entirely
    // inside the genuinely loaded input window, or pokes out only past
    // a *true* image border (where local zero-padding equals global
    // padding). This is the invariant that makes tiled execution exact.
    use ming::tiling::{check_tilable, TileGrid};
    forall("grid halo coverage", 40, random_stride_chain, |g| {
        let geom = check_tilable(g).expect("generated chains are tilable");
        for (rows, cols) in candidate_grids(g) {
            let Ok(grid) = TileGrid::build(g, rows, cols) else {
                continue; // halo too fat for this split: rejection is safe
            };
            for (ax, a) in [(0usize, &grid.h), (1usize, &grid.w)] {
                let cone = geom.cone[ax];
                for sg in &a.segs {
                    for o in sg.out_lo..sg.out_lo + a.core {
                        let need_lo = (cone.scale * o) as i64 - cone.lo as i64;
                        let need_hi = (cone.scale * o + cone.hi) as i64;
                        let win_lo = sg.in_lo as i64;
                        let win_hi = (sg.in_lo + a.local_in) as i64 - 1;
                        let left_ok = need_lo >= win_lo || sg.in_lo == 0;
                        let right_ok = need_hi <= win_hi
                            || sg.in_lo + a.local_in == a.in_extent;
                        if !(left_ok && right_ok) {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_tiled_stride2_chains_are_bit_exact() {
    // Random pooled chains: the grid-tiled simulation must reproduce
    // the untiled output bit-exactly for every buildable grid. (The old
    // width-strip subsystem *rejected* stride-2 pooling outright; this
    // is the inverted contract.)
    use ming::dse::ilp::DseConfig;
    use ming::tiling::{compile_tiled_fixed, simulate_tiled};
    let dev = DeviceSpec::kv260();
    forall("tiled stride chains bit-exact", 12, random_stride_chain, |g| {
        let x = det_input(g, 7);
        let d = build_streaming_design(g).unwrap();
        let want = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete().output;
        let mut checked = 0;
        for (rows, cols) in candidate_grids(g) {
            let Ok(tc) = compile_tiled_fixed(g, &DseConfig::new(dev.clone()), rows, cols)
            else {
                continue;
            };
            let rep = simulate_tiled(&tc, &x).unwrap();
            if rep.output != want {
                return false;
            }
            checked += 1;
        }
        // at least one grid must be buildable for every generated chain
        checked > 0
    });
}

#[test]
fn prop_fast_forward_is_bit_identical_to_exact() {
    // The steady-state fast-forward's acceptance bar on random
    // stride/kernel chains (convs + stride-2 pools, DSE-tuned): the
    // accelerated run must be indistinguishable from the exact engine —
    // outputs, cycles, high-water marks, firings, traces, and (in
    // profile mode) per-channel stall attribution and histograms.
    use ming::sim::{FfStats, SimConfig, SimContext};
    let dev = DeviceSpec::kv260();
    forall("fast-forward == exact", 12, random_stride_chain, |g| {
        let x = det_input(g, 17);
        let mut d = build_streaming_design(g).unwrap();
        solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        for profile in [false, true] {
            let run = |cfg: SimConfig| {
                let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
                ctx.set_config(cfg);
                if profile {
                    ctx.enable_profile();
                }
                ctx.run(&x).unwrap()
            };
            let fast = run(SimConfig::default());
            let exact = run(SimConfig::exact());
            assert_eq!(exact.ff, FfStats::default(), "{}: exact must not fast-forward", g.name);
            assert_eq!(fast.output, exact.output, "{}: output", g.name);
            assert_eq!(fast.cycles, exact.cycles, "{}: cycles", g.name);
            assert_eq!(fast.total_firings, exact.total_firings, "{}: firings", g.name);
            assert_eq!(fast.token_ops, exact.token_ops, "{}: token ops", g.name);
            assert_eq!(fast.fifo_high_water, exact.fifo_high_water, "{}: high water", g.name);
            assert_eq!(fast.deadlock, exact.deadlock, "{}: deadlock", g.name);
            for (a, b) in fast.traces.iter().zip(&exact.traces) {
                assert_eq!(
                    (a.firings, a.first_fire, a.last_fire, a.complete, a.stall_in, a.stall_out),
                    (b.firings, b.first_fire, b.last_fire, b.complete, b.stall_in, b.stall_out),
                    "{}/{}: trace",
                    g.name,
                    a.name
                );
            }
            if profile {
                let pf = fast.fifo_profile.expect("profile armed");
                let pe = exact.fifo_profile.expect("profile armed");
                for (a, b) in pf.channels.iter().zip(&pe.channels) {
                    assert_eq!(a.stall_wait, b.stall_wait, "{}/{}: wait", g.name, a.name);
                    assert_eq!(a.stall_full, b.stall_full, "{}/{}: full", g.name, a.name);
                    assert_eq!(a.pushed, b.pushed, "{}/{}: pushed", g.name, a.name);
                    assert_eq!(a.hist, b.hist, "{}/{}: histogram", g.name, a.name);
                    assert_eq!(a.max_occupancy, b.max_occupancy, "{}/{}: occ", g.name, a.name);
                }
            }
        }
        true
    });
}

#[test]
fn fast_forward_detects_no_false_period_on_aperiodic_deadlock() {
    // Adversarial case for the detector: undersized diamond FIFOs make
    // the run a short aperiodic transient into deadlock (the sink never
    // drains, so no shifted-state match can verify). The detector must
    // report zero periods and the deadlock report must stay identical
    // to the exact engine's.
    use ming::ir::builder::models;
    use ming::sim::{SimConfig, SimContext};
    let g = models::residual(32, 8, 8);
    let d = build_streaming_design(&g).unwrap();
    let x = det_input(&g, 29);
    let fast = simulate(&d, &x, SimMode::Dataflow).unwrap();
    let mut ctx = SimContext::new(&d, SimMode::Dataflow).unwrap();
    ctx.set_config(SimConfig::exact());
    let exact = ctx.run(&x).unwrap();
    assert!(fast.deadlock.is_some(), "diamond without FIFO sizing must deadlock");
    assert_eq!(fast.deadlock, exact.deadlock, "blocked-node reports must agree");
    assert_eq!(fast.ff.periods, 0, "no false period on an aperiodic transient");
    assert_eq!(fast.cycles, exact.cycles);
    assert_eq!(fast.output, exact.output);
    assert_eq!(fast.total_firings, exact.total_firings);
}

/// A random model paired with a random device budget straddling the
/// feasibility boundary: some cases solve, some come out infeasible —
/// and both verdicts must agree across solver configurations.
fn random_budgeted_case(g: &mut Gen) -> (ModelGraph, DeviceSpec) {
    let graph = random_graph(g);
    let rng = &mut g.rng;
    let dev = DeviceSpec::kv260()
        .with_dsp_limit(8 + rng.below(250))
        .with_bram_limit(4 + rng.below(140));
    (graph, dev)
}

#[test]
fn prop_parallel_dse_is_bit_identical_to_serial() {
    // The cold-path tentpole contract on random graphs × random device
    // budgets: the parallel branch-and-bound (forced past its volume
    // threshold) returns a DseSolution field-for-field identical to the
    // serial solver's, and the rebuilt designs emit identical HLS bytes
    // — with and without the dominance filter. Infeasible cases must
    // fail identically too, message included.
    //
    // Warm starts are held to the same bar: with the front cache
    // pre-populated and the incumbent seeded — from a self-recorded
    // optimum (accepted), from off-lattice junk picks (rejected by
    // validation), and from a neighbor solution solved under a
    // different budget (accepted or budget-rejected) — every serial
    // and parallel warm solve must reproduce the cold serial answer
    // exactly, errors included. `nodes_explored` is deliberately not
    // compared: it is an effort metric and warm seeds prune work.
    use ming::codegen::emit::emit_design;
    use ming::dse::WarmStart;
    use std::sync::Arc;
    let m = ming::obs::metrics::global();
    let h0 = m.get("dse.front_hits");
    let s0 = m.get("dse.warm_seeds");
    let j0 = m.get("dse.warm_seed_rejected");
    forall("parallel dse == serial", 18, random_budgeted_case, |(g, dev)| {
        for dominance in [true, false] {
            let serial_cfg = DseConfig::new(dev.clone())
                .with_workers(1)
                .with_dominance_filter(dominance);
            let mut d1 = build_streaming_design(g).unwrap();
            let r1 = solve(&mut d1, &serial_cfg);
            let par_cfg = DseConfig::new(dev.clone())
                .with_workers(4)
                .with_dominance_filter(dominance)
                .with_parallel_min_volume(1);

            // (a) self-primed store: a prior warm solve of this very
            // problem records its optimum, so the runs below take the
            // accepted-seed branch (and hit every node front).
            let warm_ok = Arc::new(WarmStart::new());
            {
                let mut dp = build_streaming_design(g).unwrap();
                let _ = solve(&mut dp, &serial_cfg.clone().with_warm_start(Arc::clone(&warm_ok)));
            }
            // (b) junk store: (0, 0) is never on the unroll lattice
            // (divisors are >= 1), so validation must discard it.
            let warm_junk = Arc::new(WarmStart::new());
            {
                let d = build_streaming_design(g).unwrap();
                warm_junk.record_seed(
                    WarmStart::shape_fingerprint(&d),
                    WarmStart::seed_extents(&d, dev),
                    vec![(0, 0); d.nodes.len()],
                );
            }
            // (c) neighbor store: the optimum under the unconstrained
            // budget is a real on-lattice solution that the current
            // (tighter) budget may accept or reject — either way the
            // answer must not move.
            let warm_near = Arc::new(WarmStart::new());
            {
                let mut du = build_streaming_design(g).unwrap();
                let ucfg = DseConfig::new(DeviceSpec::kv260())
                    .with_workers(1)
                    .with_dominance_filter(dominance);
                if let Ok(sol) = solve(&mut du, &ucfg) {
                    let d = build_streaming_design(g).unwrap();
                    warm_near.record_seed(
                        WarmStart::shape_fingerprint(&d),
                        WarmStart::seed_extents(&d, dev),
                        sol.chosen.iter().map(|c| (c.unroll_par, c.unroll_red)).collect(),
                    );
                }
            }

            let mut runs = vec![("parallel cold".to_string(), par_cfg.clone())];
            for (tag, warm) in
                [("primed", &warm_ok), ("junk", &warm_junk), ("neighbor", &warm_near)]
            {
                for (mode, cfg) in [("serial", &serial_cfg), ("parallel", &par_cfg)] {
                    runs.push((
                        format!("{mode} warm-{tag}"),
                        cfg.clone().with_warm_start(Arc::clone(warm)),
                    ));
                }
            }
            for (tag, cfg) in runs {
                let mut d2 = build_streaming_design(g).unwrap();
                let r2 = solve(&mut d2, &cfg);
                match (&r1, r2) {
                    (Ok(s1), Ok(s2)) => {
                        assert_eq!(s1.chosen, s2.chosen, "{} {tag}: chosen candidates", g.name);
                        assert_eq!(s1.objective, s2.objective, "{} {tag}: objective", g.name);
                        assert_eq!(s1.resources, s2.resources, "{} {tag}: resources", g.name);
                        assert_eq!(s1.dsp_used, s2.dsp_used, "{} {tag}: dsp", g.name);
                        assert_eq!(s1.bram_used, s2.bram_used, "{} {tag}: bram", g.name);
                        assert_eq!(
                            emit_design(&d1),
                            emit_design(&d2),
                            "{} {tag}: HLS bytes",
                            g.name
                        );
                    }
                    (Err(e1), Err(e2)) => {
                        assert_eq!(
                            format!("{e1:#}"),
                            format!("{e2:#}"),
                            "{} {tag}: error",
                            g.name
                        );
                    }
                    (r1, r2) => panic!(
                        "{} {tag}: feasibility diverged (serial ok={}, other ok={})",
                        g.name,
                        r1.is_ok(),
                        r2.is_ok()
                    ),
                }
            }
        }
        true
    });
    // The primed store guarantees front hits on every case, and the
    // deterministic case list always contains feasible problems (the
    // primed seed is accepted) and the junk store always rejects on
    // them. Monotone `>`: the registry is global and concurrent tests
    // may bump the counters too.
    assert!(m.get("dse.front_hits") > h0, "warm solves must hit the node-front cache");
    assert!(m.get("dse.warm_seeds") > s0, "primed seeds must be accepted");
    assert!(m.get("dse.warm_seed_rejected") > j0, "junk seeds must be rejected");
}

#[test]
fn prop_input_data_does_not_change_cycles() {
    // Streaming designs are data-oblivious: cycle counts must not depend
    // on input values (no data-dependent control flow in hardware).
    let dev = DeviceSpec::kv260();
    forall("data-oblivious timing", 15, random_graph, |g| {
        let mut d = build_streaming_design(g).unwrap();
        solve(&mut d, &DseConfig::new(dev.clone())).unwrap();
        let mut rng = XorShift::new(99);
        let n = g.inputs()[0].ty.numel();
        let x1: Vec<i32> = (0..n).map(|_| rng.i8() as i32).collect();
        let x2: Vec<i32> = (0..n).map(|_| rng.i8() as i32).collect();
        let a = simulate(&d, &x1, SimMode::Dataflow).unwrap();
        let b = simulate(&d, &x2, SimMode::Dataflow).unwrap();
        a.cycles == b.cycles
    });
}
