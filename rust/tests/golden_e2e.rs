//! Integration: the three-layer contract. The Rust cycle simulator's
//! functional output must agree **bit-exactly** with the JAX/Pallas
//! golden model (AOT HLO artifacts, executed via PJRT) for every paper
//! kernel and for every framework strategy (they all implement the same
//! math). Skips gracefully when `make artifacts` hasn't run.

use ming::baselines::framework::{compile_with, FrameworkKind};
use ming::ir::builder::models;
use ming::resources::device::DeviceSpec;
use ming::runtime::golden::GoldenModel;
use ming::sim::{simulate, SimMode};
use ming::util::prng;

fn golden() -> Option<GoldenModel> {
    match GoldenModel::open_default() {
        Ok(gm) => Some(gm),
        Err(e) => {
            eprintln!("skipping golden tests: {e:#}");
            None
        }
    }
}

fn det_input(n: usize) -> Vec<i32> {
    prng::det_tensor(prng::SEED_INPUT, n).iter().map(|&v| v as i32).collect()
}

#[test]
fn ming_matches_golden_on_all_small_kernels() {
    let Some(gm) = golden() else { return };
    let dev = DeviceSpec::kv260();
    for (kernel, size) in
        [("conv_relu", 32usize), ("cascade", 32), ("residual", 32), ("linear", 0), ("feedforward", 0)]
    {
        let key = GoldenModel::key(kernel, size);
        if !gm.available(&key) {
            continue;
        }
        let g = models::paper_kernel(kernel, size).unwrap();
        let x = det_input(g.inputs()[0].ty.numel());
        let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
        let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
        let bad = gm.verify(&key, &x, &rep.output).unwrap();
        assert_eq!(bad, 0, "{key}: {bad} mismatches");
    }
}

/// Extension workload (conv-pool-conv-pool): stride-2 sliding windows
/// and weight-less max-reduce nodes also verify bit-exact end to end.
#[test]
fn tiny_cnn_matches_golden() {
    let Some(gm) = golden() else { return };
    if !gm.available("tiny_cnn_32") {
        return;
    }
    let dev = DeviceSpec::kv260();
    let g = models::tiny_cnn(32, 4, 8);
    let x = det_input(g.inputs()[0].ty.numel());
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    let bad = gm.verify("tiny_cnn_32", &x, &rep.output).unwrap();
    assert_eq!(bad, 0, "tiny_cnn: {bad} mismatches");
    assert_eq!(rep.output.len(), 8 * 8 * 8);
}

#[test]
fn every_framework_matches_golden_on_conv() {
    let Some(gm) = golden() else { return };
    if !gm.available("conv_relu_32") {
        return;
    }
    let dev = DeviceSpec::kv260();
    let g = models::paper_kernel("conv_relu", 32).unwrap();
    let x = det_input(g.inputs()[0].ty.numel());
    for fw in FrameworkKind::all() {
        let d = compile_with(fw, &g, &dev).unwrap();
        let rep = simulate(&d, &x, SimMode::of(d.style)).unwrap().expect_complete();
        let bad = gm.verify("conv_relu_32", &x, &rep.output).unwrap();
        assert_eq!(bad, 0, "{}: {bad} mismatches vs golden", fw.name());
    }
}

#[test]
fn golden_runs_at_224_scale() {
    let Some(gm) = golden() else { return };
    if !gm.available("conv_relu_224") {
        return;
    }
    let dev = DeviceSpec::kv260();
    let g = models::paper_kernel("conv_relu", 224).unwrap();
    let x = det_input(g.inputs()[0].ty.numel());
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    let rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    let bad = gm.verify("conv_relu_224", &x, &rep.output).unwrap();
    assert_eq!(bad, 0);
    assert_eq!(rep.output.len(), 224 * 224 * 8);
}

#[test]
fn golden_rejects_wrong_inputs() {
    let Some(gm) = golden() else { return };
    if !gm.available("linear_0") {
        return;
    }
    // wrong input length must error, not crash
    assert!(gm.run("linear_0", &[1, 2, 3]).is_err());
    // wrong output length in verify must error
    let x = det_input(512 * 128);
    assert!(gm.verify("linear_0", &x, &[0i32; 7]).is_err());
}

#[test]
fn golden_detects_injected_corruption() {
    let Some(gm) = golden() else { return };
    if !gm.available("linear_0") {
        return;
    }
    let dev = DeviceSpec::kv260();
    let g = models::paper_kernel("linear", 0).unwrap();
    let x = det_input(g.inputs()[0].ty.numel());
    let d = compile_with(FrameworkKind::Ming, &g, &dev).unwrap();
    let mut rep = simulate(&d, &x, SimMode::Dataflow).unwrap().expect_complete();
    // flip one value: verification must catch exactly one mismatch
    rep.output[1234] ^= 1;
    let bad = gm.verify("linear_0", &x, &rep.output).unwrap();
    assert_eq!(bad, 1, "corruption must be detected");
}
