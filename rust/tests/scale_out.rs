//! Integration tests for the sharded, cache-backed compile service:
//! the acceptance criteria of the scale-out refactor.
//!
//! * A repeated sweep with a design cache performs **zero** ILP solves
//!   on the second run (asserted via the cache's solve counter).
//! * Cached-vs-fresh compilation produces byte-identical designs (the
//!   determinism property), flat and tiled.
//! * Cache keys miss on device or config change; corrupt cache files
//!   degrade to misses, never errors.
//! * A 2-shard sweep, spooled and merged, is row-identical to the
//!   unsharded sweep; resume skips already-spooled jobs.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;

use ming::baselines::framework::FrameworkKind;
use ming::codegen::emit::emit_tiled_design;
use ming::codegen::emit_design;
use ming::coordinator::cache::DesignCache;
use ming::coordinator::report::{self, Cell};
use ming::coordinator::service::{CompileService, Shard, SweepConfig};
use ming::coordinator::spool;

use ming::dse::ilp::{solve_with_tiling_fallback, Compiled, DseConfig};
use ming::ir::builder::models;
use ming::ir::fingerprint::problem_fingerprint;
use ming::ir::graph::TilingHint;
use ming::resources::device::DeviceSpec;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ming-scaleout-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn small_sweep() -> SweepConfig {
    SweepConfig {
        workloads: vec![("conv_relu".into(), 32), ("cascade".into(), 32), ("linear".into(), 0)],
        frameworks: vec![FrameworkKind::Vanilla, FrameworkKind::Ming],
        device: DeviceSpec::kv260(),
        estimate_only: true,
    }
}

fn cells_of(results: &[Result<ming::coordinator::JobResult, String>]) -> Vec<Cell> {
    results.iter().filter_map(|r| r.as_ref().ok().map(report::cell)).collect()
}

#[test]
fn repeated_table2_sweep_with_cache_performs_zero_solves() {
    // The headline acceptance criterion, on the real Table-II job list
    // (estimate-only keeps the 224-sized simulations out of the test).
    let mut cfg = SweepConfig::table2(DeviceSpec::kv260());
    cfg.estimate_only = true;
    let cache = Arc::new(DesignCache::in_memory());
    let svc = CompileService::new(2).with_cache(cache.clone());

    let first = svc.run_sweep(&cfg);
    let after_first = cache.stats();
    assert!(after_first.solves > 0, "cold sweep must actually solve");
    assert!(after_first.stores > 0);

    let second = svc.run_sweep(&cfg);
    let after_second = cache.stats();
    assert_eq!(
        after_second.solves, after_first.solves,
        "warm sweep must perform zero ILP solves"
    );
    assert!(after_second.hits > after_first.hits, "warm sweep must hit the cache");
    assert_eq!(after_second.corrupt, 0);

    // and the rendered table is identical run-to-run
    assert_eq!(
        report::render_table2(&cells_of(&first)),
        report::render_table2(&cells_of(&second))
    );
}

#[test]
fn cached_flat_design_is_byte_identical_to_fresh() {
    let g = models::conv_relu(32, 8, 8);
    let dev = DeviceSpec::kv260();
    let fresh = match solve_with_tiling_fallback(&g, &DseConfig::new(dev.clone())).unwrap() {
        Compiled::Flat(d, sol) => (d, sol),
        Compiled::Tiled(_) => panic!("conv_relu@32 is flat-feasible"),
    };

    let cache = Arc::new(DesignCache::in_memory());
    let cfg = DseConfig::new(dev).with_cache(cache.clone());
    let _cold = solve_with_tiling_fallback(&g, &cfg).unwrap();
    let warm = match solve_with_tiling_fallback(&g, &cfg).unwrap() {
        Compiled::Flat(d, sol) => (d, sol),
        Compiled::Tiled(_) => panic!("cache must not change the outcome kind"),
    };
    assert_eq!(cache.stats().solves, 1, "second compile must be a pure hit");
    assert_eq!(fresh.1.objective, warm.1.objective);
    assert_eq!(fresh.1.resources, warm.1.resources);
    // byte-identity: internal representation and emitted HLS
    assert_eq!(format!("{:?}", fresh.0), format!("{:?}", warm.0));
    assert_eq!(emit_design(&fresh.0), emit_design(&warm.0));
}

#[test]
fn cached_tiled_design_is_byte_identical_to_fresh() {
    // BRAM-starved conv: the full-width line buffers alone cost 4 blocks
    // at any unroll (400·8·8 bits > 18K per row, 2 rows), so only
    // grid-tiled designs fit a 3-block budget.
    let g = models::conv_relu(400, 8, 8);
    let dev = DeviceSpec::kv260().with_bram_limit(3);
    let fresh = match solve_with_tiling_fallback(&g, &DseConfig::new(dev.clone())).unwrap() {
        Compiled::Tiled(tc) => tc,
        Compiled::Flat(..) => panic!("BRAM-starved workload must tile"),
    };

    let cache = Arc::new(DesignCache::in_memory());
    let cfg = DseConfig::new(dev).with_cache(cache.clone());
    let _cold = solve_with_tiling_fallback(&g, &cfg).unwrap();
    let solves_cold = cache.stats().solves;
    assert!(solves_cold > 0);
    let warm = match solve_with_tiling_fallback(&g, &cfg).unwrap() {
        Compiled::Tiled(tc) => tc,
        Compiled::Flat(..) => panic!("cache must not change the outcome kind"),
    };
    assert_eq!(
        cache.stats().solves,
        solves_cold,
        "warm tiled compile must re-run neither the grid search nor any cell DSE"
    );
    assert_eq!(fresh.grid.rows(), warm.grid.rows());
    assert_eq!(fresh.grid.cols(), warm.grid.cols());
    assert_eq!(fresh.solution.objective, warm.solution.objective);
    assert_eq!(format!("{:?}", fresh.cell), format!("{:?}", warm.cell));
    assert_eq!(emit_tiled_design(&fresh), emit_tiled_design(&warm));
}

#[test]
fn infeasible_flat_verdict_is_negative_cached_in_the_fallback() {
    // A workload that is infeasible flat *and* untilable (rank-2 linear
    // with no DSP budget): the first compile pays the flat
    // branch-and-bound proof; every repeat reuses the cached verdict —
    // zero further ILP solves even though the compile still errors.
    let g = models::linear();
    let cache = Arc::new(DesignCache::in_memory());
    let cfg = DseConfig::new(DeviceSpec::kv260().with_dsp_limit(0)).with_cache(cache.clone());

    let e1 = solve_with_tiling_fallback(&g, &cfg).unwrap_err();
    assert!(format!("{e1:#}").contains("fallback"), "{e1:#}");
    let solves1 = cache.stats().solves;
    assert_eq!(solves1, 1, "first run proves infeasibility once");

    let e2 = solve_with_tiling_fallback(&g, &cfg).unwrap_err();
    assert_eq!(
        cache.stats().solves,
        1,
        "repeat compile must reuse the cached infeasibility verdict"
    );
    assert!(cache.stats().hits >= 1);
    assert!(format!("{e2:#}").contains("cached verdict"), "{e2:#}");
}

#[test]
fn tile_grid_search_negative_caches_failing_cells() {
    // The BRAM-starved conv walks grid candidates whose cells do not
    // fit before reaching the winner. A second *direct* compile_tiled
    // (no fallback wrapper, so the whole-outcome cache entry is not
    // consulted) must re-prove none of those dead ends: every cell
    // probe — failed or won — hits the cache.
    use ming::tiling::compile_tiled;
    let g = models::conv_relu(400, 8, 8);
    let dev = DeviceSpec::kv260().with_bram_limit(3);
    let cache = Arc::new(DesignCache::in_memory());
    let cfg = DseConfig::new(dev).with_cache(cache.clone());

    let tc1 = compile_tiled(&g, &cfg).unwrap();
    let solves_cold = cache.stats().solves;
    assert!(solves_cold > 0);

    let tc2 = compile_tiled(&g, &cfg).unwrap();
    assert_eq!(
        cache.stats().solves,
        solves_cold,
        "the repeated grid search must perform zero cell ILP solves"
    );
    assert_eq!((tc1.grid.rows(), tc1.grid.cols()), (tc2.grid.rows(), tc2.grid.cols()));
    assert_eq!(format!("{:?}", tc1.cell), format!("{:?}", tc2.cell));
}

#[test]
fn cache_keys_miss_on_device_or_config_change() {
    let g = models::conv_relu(32, 8, 8);
    let kv = DeviceSpec::kv260();
    let cache = Arc::new(DesignCache::in_memory());

    let cfg = DseConfig::new(kv.clone()).with_cache(cache.clone());
    solve_with_tiling_fallback(&g, &cfg).unwrap();
    assert_eq!(cache.stats().solves, 1);

    // a tighter DSP budget is a different problem: must miss and re-solve
    let capped = DseConfig::new(kv.with_dsp_limit(250)).with_cache(cache.clone());
    solve_with_tiling_fallback(&g, &capped).unwrap();
    assert_eq!(cache.stats().solves, 2, "device change must miss");

    // a different device likewise
    let zcu = DseConfig::new(DeviceSpec::zcu104()).with_cache(cache.clone());
    solve_with_tiling_fallback(&g, &zcu).unwrap();
    assert_eq!(cache.stats().solves, 3, "different device must miss");

    // a tiling-hint change alters the problem fingerprint too
    let mut hinted = g.clone();
    hinted.tiling =
        Some(TilingHint { tile_width: Some(8), tile_height: None, max_tiles: None });
    assert_ne!(
        problem_fingerprint(&g, &DeviceSpec::kv260()),
        problem_fingerprint(&hinted, &DeviceSpec::kv260())
    );

    // and re-running any of the above is all hits, no new solves
    solve_with_tiling_fallback(&g, &cfg).unwrap();
    solve_with_tiling_fallback(&g, &capped).unwrap();
    solve_with_tiling_fallback(&g, &zcu).unwrap();
    assert_eq!(cache.stats().solves, 3);
}

#[test]
fn corrupt_cache_file_degrades_to_miss_not_error() {
    let dir = tmp_dir("corrupt");
    let g = models::conv_relu(32, 8, 8);
    let dev = DeviceSpec::kv260();

    // populate the disk cache, then vandalize every entry
    {
        let cache = Arc::new(DesignCache::at_dir(&dir).unwrap());
        let cfg = DseConfig::new(dev.clone()).with_cache(cache.clone());
        solve_with_tiling_fallback(&g, &cfg).unwrap();
        assert!(cache.stats().stores > 0);
    }
    let mut vandalized = 0;
    for e in std::fs::read_dir(&dir).unwrap() {
        let p = e.unwrap().path();
        if p.extension().is_some_and(|x| x == "json") {
            std::fs::write(&p, "{torn mid-write").unwrap();
            vandalized += 1;
        }
    }
    assert!(vandalized > 0, "the disk cache must have written entries");

    // a fresh process (fresh memory tier) must fall back to solving
    let cache = Arc::new(DesignCache::at_dir(&dir).unwrap());
    let cfg = DseConfig::new(dev).with_cache(cache.clone());
    let compiled = solve_with_tiling_fallback(&g, &cfg).unwrap();
    assert!(matches!(compiled, Compiled::Flat(..)));
    let s = cache.stats();
    assert_eq!(s.solves, 1, "corrupt entry must degrade to a real solve");
    assert!(s.corrupt > 0, "the corruption must be counted");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn disk_cache_is_shared_across_service_instances() {
    // Two CompileService instances with *separate* in-memory tiers but
    // one cache dir model two processes (shards) sharing solutions.
    let dir = tmp_dir("shared");
    let cfg = small_sweep();

    let svc1 = CompileService::new(2)
        .with_cache(Arc::new(DesignCache::at_dir(&dir).unwrap()));
    svc1.run_sweep(&cfg);
    let solves1 = svc1.cache().unwrap().stats().solves;
    assert!(solves1 > 0);

    let svc2 = CompileService::new(2)
        .with_cache(Arc::new(DesignCache::at_dir(&dir).unwrap()));
    svc2.run_sweep(&cfg);
    let s2 = svc2.cache().unwrap().stats();
    assert_eq!(s2.solves, 0, "a second process must reuse the first one's designs");
    assert!(s2.hits > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn two_shard_sweep_merges_row_identical_to_unsharded() {
    let cfg = small_sweep();
    let svc = CompileService::new(2);

    // unsharded reference
    let unsharded = report::render_table2(&cells_of(&svc.run_sweep(&cfg)));

    // two shards, spooled through the real JSONL encoding, then merged
    let total = CompileService::jobs(&cfg).len();
    let sweep = CompileService::sweep_id(&cfg);
    let ids: Vec<String> = CompileService::jobs(&cfg).iter().map(|j| j.id()).collect();
    let mut lines = Vec::new();
    for index in 0..2 {
        let shard = Shard { index, count: 2 };
        for (seq, outcome) in svc.run_shard(&cfg, shard, &BTreeSet::new()) {
            lines.push(spool::record_line(sweep, "table2", seq, total, &ids[seq], &outcome));
        }
    }
    let records: Vec<_> =
        lines.iter().map(|l| spool::parse_line(l).unwrap()).collect();
    let merged = spool::merge(records).unwrap();
    assert!(merged.failures.is_empty());
    assert!(merged.missing.is_empty());
    assert_eq!(
        report::render_table2(&merged.cells),
        unsharded,
        "merged shard output must be row-identical to the unsharded sweep"
    );
}

#[test]
fn sweep_outputs_are_bit_identical_across_worker_counts() {
    // The scheduler's determinism contract, end to end: the same sweep
    // run serially (`--workers 1`) and at widths 2, 5, and 16 — with
    // nested parallelism enabled, so sweep fan-out, DSE subtree groups,
    // and the speculative grid search all share the pool — must agree
    // field for field, table row for row, and through the spool
    // encode/merge path. The workload mixes flat cells with the
    // oversized vgg3@512 straggler so the tiled path is on the clock.
    use ming::coordinator::{JobResult, Scheduler};
    let mut cfg = small_sweep();
    cfg.workloads.push(("vgg3".into(), 512));

    // Every solution-bearing field; stage wall-times are the one
    // legitimately nondeterministic part of a result and stay out.
    let fingerprint = |results: &[(usize, Result<JobResult, String>)]| -> Vec<String> {
        results
            .iter()
            .map(|(seq, r)| match r {
                Ok(r) => format!(
                    "{seq} {} cycles={} macs={} tiles={} util={:?} err={:?}",
                    r.job.id(),
                    r.cycles,
                    r.macs,
                    r.tiles,
                    r.util,
                    r.error
                ),
                Err(e) => format!("{seq} ERR {e}"),
            })
            .collect()
    };
    let table_of = |results: &[(usize, Result<JobResult, String>)]| -> String {
        let cells: Vec<Cell> =
            results.iter().filter_map(|(_, r)| r.as_ref().ok().map(report::cell)).collect();
        report::render_table2(&cells)
    };
    let merged_table_of = |results: &[(usize, Result<JobResult, String>)]| -> String {
        let total = CompileService::jobs(&cfg).len();
        let sweep = CompileService::sweep_id(&cfg);
        let ids: Vec<String> = CompileService::jobs(&cfg).iter().map(|j| j.id()).collect();
        let records: Vec<_> = results
            .iter()
            .map(|(seq, outcome)| {
                let line =
                    spool::record_line(sweep, "table2", *seq, total, &ids[*seq], outcome);
                spool::parse_line(&line).unwrap()
            })
            .collect();
        report::render_table2(&spool::merge(records).unwrap().cells)
    };

    let reference = CompileService::new(1).run_shard(&cfg, Shard::full(), &BTreeSet::new());
    let (ref_fp, ref_table) = (fingerprint(&reference), table_of(&reference));
    let ref_merged = merged_table_of(&reference);

    for n in [2usize, 5, 16] {
        let sched = Scheduler::new(n);
        let svc = CompileService::new(n).with_scheduler(sched.handle());
        let got = svc.run_shard(&cfg, Shard::full(), &BTreeSet::new());
        assert_eq!(fingerprint(&got), ref_fp, "workers={n}: results diverged");
        assert_eq!(table_of(&got), ref_table, "workers={n}: rendered table diverged");
        assert_eq!(
            merged_table_of(&got),
            ref_merged,
            "workers={n}: spool-merged table diverged"
        );
    }
}

#[test]
fn emitted_hls_is_byte_identical_across_worker_counts() {
    // The generated C++ — flat and grid-tiled — must not depend on how
    // wide the solver fanned out. `parallel_min_volume(1)` forces the
    // parallel branch-and-bound even on these small lattices.
    let flat_g = models::conv_relu(32, 8, 8);
    let tiled_g = models::conv_relu(400, 8, 8);
    let flat_dev = DeviceSpec::kv260();
    let tiled_dev = DeviceSpec::kv260().with_bram_limit(3);

    let flat_ref = match solve_with_tiling_fallback(
        &flat_g,
        &DseConfig::new(flat_dev.clone()).with_workers(1),
    )
    .unwrap()
    {
        Compiled::Flat(d, _) => emit_design(&d),
        Compiled::Tiled(_) => panic!("conv_relu@32 is flat-feasible"),
    };
    let tiled_ref = match solve_with_tiling_fallback(
        &tiled_g,
        &DseConfig::new(tiled_dev.clone()).with_workers(1),
    )
    .unwrap()
    {
        Compiled::Tiled(tc) => emit_tiled_design(&tc),
        Compiled::Flat(..) => panic!("BRAM-starved workload must tile"),
    };

    for n in [2usize, 5, 16] {
        let cfg = DseConfig::new(flat_dev.clone()).with_workers(n).with_parallel_min_volume(1);
        match solve_with_tiling_fallback(&flat_g, &cfg).unwrap() {
            Compiled::Flat(d, _) => assert_eq!(
                emit_design(&d),
                flat_ref,
                "workers={n}: flat HLS diverged"
            ),
            Compiled::Tiled(_) => panic!("workers={n}: outcome kind changed"),
        }
        let cfg = DseConfig::new(tiled_dev.clone()).with_workers(n).with_parallel_min_volume(1);
        match solve_with_tiling_fallback(&tiled_g, &cfg).unwrap() {
            Compiled::Tiled(tc) => assert_eq!(
                emit_tiled_design(&tc),
                tiled_ref,
                "workers={n}: tiled HLS diverged"
            ),
            Compiled::Flat(..) => panic!("workers={n}: outcome kind changed"),
        }
    }
}

#[test]
fn resume_skips_already_spooled_jobs() {
    let cfg = small_sweep();
    let svc = CompileService::new(1);
    let total = CompileService::jobs(&cfg).len();
    let sweep = CompileService::sweep_id(&cfg);
    let ids: Vec<String> = CompileService::jobs(&cfg).iter().map(|j| j.id()).collect();

    // first run "crashes" halfway: records stream to disk per job (the
    // streaming hook), and only shard 0/2's jobs made it
    let dir = tmp_dir("resume");
    std::fs::create_dir_all(&dir).unwrap();
    let path = spool::shard_file(&dir, Shard::full());
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&path).unwrap();
        svc.run_shard_streaming(
            &cfg,
            Shard { index: 0, count: 2 },
            &BTreeSet::new(),
            |seq, outcome| {
                let line = spool::record_line(sweep, "table2", seq, total, &ids[seq], outcome);
                writeln!(f, "{line}").unwrap();
            },
        );
    }

    // resume the full sweep against the spool: exactly the missing
    // (odd-seq) jobs run
    let (existing, torn) = spool::read_spool_file(&path).unwrap();
    assert_eq!(torn, 0);
    assert!(existing.iter().all(|r| r.sweep == sweep), "sweep id rides along");
    let done: BTreeSet<usize> = existing.iter().map(|r| r.seq).collect();
    let rest = svc.run_shard(&cfg, Shard::full(), &done);
    let rest_seqs: Vec<usize> = rest.iter().map(|(s, _)| *s).collect();
    let expect: Vec<usize> = (0..total).filter(|s| s % 2 == 1).collect();
    assert_eq!(rest_seqs, expect, "resume must run exactly the unspooled jobs");

    // spool union covers the sweep completely and merges cleanly
    let mut all = existing;
    for (seq, outcome) in &rest {
        let line = spool::record_line(sweep, "table2", *seq, total, &ids[*seq], outcome);
        all.push(spool::parse_line(&line).unwrap());
    }
    let merged = spool::merge(all).unwrap();
    assert!(merged.missing.is_empty());
    assert_eq!(merged.cells.len(), total);
    let _ = std::fs::remove_dir_all(&dir);
}
