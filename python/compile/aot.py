"""AOT lowering: the five paper kernels -> HLO text artifacts.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly.
(See /opt/xla-example/load_hlo + its gen_hlo.py.)

Usage:
    python -m compile.aot --out-dir ../artifacts          # all variants
    python -m compile.aot --only conv_relu_32 --out-dir ../artifacts

Each artifact `<name>_<size>.hlo.txt` is the Pallas-backed (interpret=True)
kernel lowered at its concrete input shape, taking one int32 tensor and
returning a 1-tuple of int32. A sibling `<name>_<size>.meta` records the
shapes for the Rust loader. Re-running is a no-op when inputs are older
than outputs (the Makefile also guards this).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def scale_out_args(design_cache=None, workers=None, shard=None, spool=None):
    """The scale-out flag tail shared by every emitted `ming` command:
    pass-through of the Rust CLI's design-cache / worker-pool / shard /
    spool flags (see `rust/src/main.rs`). Returns a flat argv fragment.
    """
    argv = []
    if design_cache:
        argv += ["--design-cache", str(design_cache)]
    if workers:
        argv += ["--workers", str(workers)]
    if shard:
        argv += ["--shard", str(shard)]
    if spool:
        argv += ["--spool", str(spool)]
    return argv


def ming_import_argv(model_path, device=None, **scale_out):
    """`ming import` invocation for one emitted model JSON, carrying the
    scale-out flags through (the design cache makes repeat imports of
    the same model/device pair skip the DSE entirely)."""
    argv = ["ming", "import", "--model", str(model_path)]
    if device:
        argv += ["--device", device]
    argv += scale_out_args(**scale_out)
    return argv


def ming_sweep_argv(device=None, estimate_only=False, **scale_out):
    """`ming table2` sweep invocation with the scale-out flags passed
    through; with --shard/--spool this is one fan-out slice of the sweep
    (stitch with `ming merge-sweep --spool <dir>`)."""
    argv = ["ming", "table2"]
    if device:
        argv += ["--device", device]
    if estimate_only:
        argv += ["--estimate-only"]
    argv += scale_out_args(**scale_out)
    return argv


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: without it the text printer elides big
    # weight literals as `constant({...})`, which the xla_extension
    # 0.5.1 parser silently mis-reads (values come back as iota!).
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(name: str, size: int, shape) -> str:
    fn = model.build(name, size, use_pallas=True)
    spec = jax.ShapeDtypeStruct(shape, jax.numpy.int32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def out_shape(name: str, size: int):
    if name in ("linear", "feedforward"):
        return (model.LIN_M, model.LIN_N)
    if name == "tiny_cnn":
        return (size // 4, size // 4, model.CONV_F)
    return (size, size, model.CONV_F)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="restrict to one variant key, e.g. conv_relu_32")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--emit-model-json",
        action="store_true",
        help="also write <key>.model.json (the Rust `ming import` schema, "
        "with tile-grid metadata and per-layer weight_elems/weight_bits "
        "for ROM accounting) for chain-shaped kernels",
    )
    ap.add_argument(
        "--tile-width",
        type=int,
        default=None,
        help="tile_width hint carried in the emitted model JSON",
    )
    ap.add_argument(
        "--tile-height",
        type=int,
        default=None,
        help="tile_height hint carried in the emitted model JSON "
        "(upgrades the tiling metadata to the 2-D grid form)",
    )
    ap.add_argument(
        "--design-cache",
        default=None,
        help="pass-through: --design-cache dir for the printed `ming` "
        "commands (content-addressed design reuse across runs/shards)",
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=None,
        help="pass-through: --workers N for the printed `ming` sweep command",
    )
    ap.add_argument(
        "--shard",
        default=None,
        help="pass-through: --shard i/n for the printed `ming` sweep command",
    )
    ap.add_argument(
        "--spool",
        default=None,
        help="pass-through: --spool dir for the printed `ming` sweep command",
    )
    args = ap.parse_args()
    scale_out = dict(
        design_cache=args.design_cache,
        workers=args.workers,
        shard=args.shard,
        spool=args.spool,
    )

    os.makedirs(args.out_dir, exist_ok=True)
    wrote = 0
    for name, size, shape in model.artifact_variants():
        key = f"{name}_{size}"
        if args.only and key != args.only:
            continue
        if args.emit_model_json:
            try:
                doc = model.json_model(
                    name, size,
                    tile_width=args.tile_width,
                    tile_height=args.tile_height,
                )
            except ValueError:
                print(f"[aot] no model json for {key} (not chain-shaped)")
            else:
                mpath = os.path.join(args.out_dir, f"{key}.model.json")
                with open(mpath, "w") as f:
                    json.dump(doc, f, indent=1, sort_keys=True)
                print(f"[aot] wrote {mpath}")
                print("[aot] compile with: "
                      + " ".join(ming_import_argv(
                          mpath, design_cache=args.design_cache)))
        hlo_path = os.path.join(args.out_dir, f"{key}.hlo.txt")
        meta_path = os.path.join(args.out_dir, f"{key}.meta")
        if not args.force and os.path.exists(hlo_path):
            src_mtime = max(
                os.path.getmtime(p)
                for p in [
                    __file__,
                    os.path.join(os.path.dirname(__file__), "model.py"),
                ]
            )
            if os.path.getmtime(hlo_path) >= src_mtime:
                print(f"[aot] up-to-date: {key}")
                continue
        print(f"[aot] lowering {key} (input {shape}) ...", flush=True)
        text = lower_variant(name, size, shape)
        with open(hlo_path, "w") as f:
            f.write(text)
        oshape = out_shape(name, size)
        with open(meta_path, "w") as f:
            f.write(
                "in_shape=%s\nout_shape=%s\nrequant_shift=%d\n"
                % (
                    ",".join(map(str, shape)),
                    ",".join(map(str, oshape)),
                    6,
                )
            )
        print(f"[aot] wrote {hlo_path} ({len(text)} chars)")
        wrote += 1
    if args.shard or args.spool or args.design_cache:
        print("[aot] sweep with:   "
              + " ".join(ming_sweep_argv(estimate_only=True, **scale_out)))
        if args.spool:
            print(f"[aot] then merge:   ming merge-sweep --spool {args.spool}")
    print(f"[aot] done ({wrote} lowered)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
