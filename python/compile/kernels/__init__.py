"""L1 Pallas kernels + pure-jnp oracle for the MING golden model."""

from . import ref  # noqa: F401
from .conv2d_stream import conv2d_stream, vmem_footprint_bytes  # noqa: F401
from .matmul_stream import matmul_stream  # noqa: F401
