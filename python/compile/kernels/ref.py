"""Pure-jnp reference oracle for the MING golden model.

These functions define the *semantics* that every other layer of the stack
must match exactly:

  * the Pallas kernels in this package (checked by pytest),
  * the AOT-lowered HLO artifacts executed from Rust via PJRT,
  * the Rust cycle-level dataflow simulator's functional output
    (checked by `ming verify` / examples/e2e_cnn.rs).

All CNN kernels follow the paper's edge-inference setting: 8-bit integer
post-training quantization. Arithmetic contract (mirrored bit-exactly in
Rust `sim::process`):

  - activations and weights are int8,
  - convolution / linear accumulate in int32,
  - ReLU is applied on the int32 accumulator,
  - requantization is an arithmetic right shift by REQUANT_SHIFT followed
    by clamping to [-128, 127] (floor rounding, i.e. plain `>>`).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Right-shift applied when requantizing an int32 accumulator back to int8.
# 3x3x8 int8 MACs peak around 2^20; >>6 keeps typical outputs in range
# while still exercising the clamp on adversarial inputs.
REQUANT_SHIFT = 6

I8_MIN, I8_MAX = -128, 127


def requantize(acc):
    """int32 accumulator -> int8 activation (shift + clamp, floor rounding)."""
    shifted = jnp.right_shift(acc, REQUANT_SHIFT)
    return jnp.clip(shifted, I8_MIN, I8_MAX).astype(jnp.int8)


def relu_i32(acc):
    """ReLU on the int32 accumulator (pre-requantization)."""
    return jnp.maximum(acc, 0)


def conv2d_i8(x, w, stride: int = 1, padding: int = 1):
    """Quantized 2-D convolution.

    x: (H, W, C)   int8 input feature map
    w: (F, K, K, C) int8 weights
    returns (H_out, W_out, F) int32 accumulators (no activation).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    h, wid, c = x.shape
    f, k, _, c2 = w.shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    xp = jnp.pad(x.astype(jnp.int32), ((padding, padding), (padding, padding), (0, 0)))
    h_out = (h + 2 * padding - k) // stride + 1
    w_out = (wid + 2 * padding - k) // stride + 1
    # im2col: gather (h_out, w_out, k, k, c) windows then contract with w.
    win = jnp.stack(
        [
            jnp.stack(
                [
                    xp[r : r + h_out * stride : stride, s : s + w_out * stride : stride, :]
                    for s in range(k)
                ],
                axis=2,
            )
            for r in range(k)
        ],
        axis=2,
    )  # (h_out, w_out, k, k, c)
    acc = jnp.einsum("hwijc,fijc->hwf", win, w.astype(jnp.int32))
    return acc.astype(jnp.int32)


def conv_relu_i8(x, w, stride: int = 1, padding: int = 1):
    """Conv2D -> ReLU -> requantize: the paper's single-layer kernel."""
    return requantize(relu_i32(conv2d_i8(x, w, stride, padding)))


def linear_i8(x, w):
    """Quantized matmul: x (M, K) int8 @ w (K, N) int8 -> (M, N) int32."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))


def add_i8(a, b):
    """Residual addition of two int8 maps -> int8 (saturating)."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, I8_MIN, I8_MAX).astype(jnp.int8)


def maxpool2d_i8(x, k: int = 2, stride: int = 2):
    """Max-pooling over (H, W, C) int8 maps -> (H_out, W_out, C) int8."""
    assert x.dtype == jnp.int8
    h, w, c = x.shape
    h_out = (h - k) // stride + 1
    w_out = (w - k) // stride + 1
    win = jnp.stack(
        [
            jnp.stack(
                [
                    x[r : r + h_out * stride : stride, s : s + w_out * stride : stride, :]
                    for s in range(k)
                ],
                axis=2,
            )
            for r in range(k)
        ],
        axis=2,
    )  # (h_out, w_out, k, k, c)
    return jnp.max(win, axis=(2, 3))


# ---------------------------------------------------------------------------
# The five paper kernels (evaluation section, Table II)
# ---------------------------------------------------------------------------

def kernel_conv_relu(x, w1):
    """Conv+ReLU (single layer)."""
    return conv_relu_i8(x, w1)


def kernel_cascade(x, w1, w2):
    """Cascade Conv Block: conv -> relu -> conv -> relu."""
    t = conv_relu_i8(x, w1)
    return conv_relu_i8(t, w2)


def kernel_residual(x, w1, w2):
    """Residual Block: y = sat(relu(x + requant(conv(relu_conv(x))))).

    Diamond-shaped dataflow: the input feeds both the conv chain and the
    skip connection — this is the FIFO-deadlock case the paper's DSE
    sizes buffers for.
    """
    t = conv_relu_i8(x, w1)
    u = requantize(conv2d_i8(t, w2))  # second conv: requant, no relu pre-add
    s = x.astype(jnp.int32) + u.astype(jnp.int32)
    s = jnp.maximum(s, 0)
    return jnp.clip(s, I8_MIN, I8_MAX).astype(jnp.int8)


def kernel_tiny_cnn(x, w1, w2):
    """Extension workload: conv -> relu -> pool -> conv -> relu -> pool."""
    t = conv_relu_i8(x, w1)
    t = maxpool2d_i8(t, 2, 2)
    t = conv_relu_i8(t, w2)
    return maxpool2d_i8(t, 2, 2)


def kernel_linear(x, w1):
    """Linear: (512,128)@(128,128) with ReLU + requantize."""
    return requantize(relu_i32(linear_i8(x, w1)))


def kernel_feedforward(x, w1, w2):
    """Feed Forward: two cascaded Linear layers with ReLU between."""
    t = requantize(relu_i32(linear_i8(x, w1)))
    return requantize(relu_i32(linear_i8(t, w2)))


# ---------------------------------------------------------------------------
# Deterministic test-vector generation, mirrored bit-exactly by
# rust/src/util/prng.rs::det_i8 so both sides can regenerate identical
# weights/inputs without shipping tensors around.
# ---------------------------------------------------------------------------

_MIX1 = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xD1B54A32D192ED03)


def det_i8(seed: int, n: int) -> np.ndarray:
    """n deterministic int8 values for `seed`; same formula as Rust."""
    i = np.arange(1, n + 1, dtype=np.uint64)
    with np.errstate(over="ignore"):
        v = (i * _MIX1) ^ ((np.uint64(seed) + np.uint64(1)) * _MIX2)
        v = (v >> np.uint64(32)) & np.uint64(0xFF)
    return v.astype(np.uint8).view(np.int8)


def det_tensor(seed: int, shape) -> np.ndarray:
    return det_i8(seed, int(np.prod(shape))).reshape(shape)
