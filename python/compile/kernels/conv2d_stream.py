"""Pallas line-buffer convolution kernel — the paper's compute hot-spot.

MING's FPGA design streams the input feature map row by row through a
`(K-1) x W` line buffer; each arriving row completes a `K x W` slab from
which one full output row is computed and pushed to the output stream
(paper §IV-B).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the line buffer becomes a
K-row *slab resident in VMEM*; the per-pixel `K*K*C` dot products of one
output row are batched into a single `(W_out, K*K*C) @ (K*K*C, F)` matmul
so the MXU — not scalar DSP-style MACs — does the work. The Pallas grid
walks output rows, i.e. the streaming dimension: grid step `r` touches
input rows `[r*stride, r*stride+K)` only, exactly the paper's slab
schedule. Because adjacent slabs overlap by `K-stride` rows (BlockSpec
blocks cannot overlap), the kernel receives the padded input whole and
slices its slab with `pl.dslice` — on a real TPU this slice is the
per-step HBM->VMEM DMA of one new row while `K-1` rows stay resident,
i.e. the line buffer.

Run with interpret=True: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import I8_MAX, I8_MIN, REQUANT_SHIFT


def _conv_row_kernel(xp_ref, w_ref, o_ref, *, k: int, stride: int, w_out: int,
                     relu: bool, requant: bool):
    """Compute one output row from the K-row input slab.

    xp_ref: (H_pad, W_pad, C) padded input (int8) — whole map; only the
            current K-row slab is read (the VMEM line buffer).
    w_ref:  (K*K*C, F) pre-flattened weights (int8).
    o_ref:  (1, W_out, F) output row (int32).
    """
    r = pl.program_id(0)
    # --- line-buffer fill: the K-row slab for output row r -------------
    slab = xp_ref[pl.dslice(r * stride, k), :, :].astype(jnp.int32)  # (K, W_pad, C)

    # --- window extraction: one (W_out, K*K*C) patch matrix ------------
    # Columns c*stride .. c*stride+K for every output column c. Gather by
    # stacking K shifted views, which keeps everything vectorized.
    cols = [slab[:, j : j + (w_out - 1) * stride + 1 : stride, :] for j in range(k)]
    # each cols[j]: (K, W_out, C); stack -> (K, K, W_out, C)
    win = jnp.stack(cols, axis=1)
    patches = jnp.transpose(win, (2, 0, 1, 3)).reshape(w_out, -1)  # (W_out, K*K*C)

    # --- MXU contraction: one matmul per output row ---------------------
    acc = jax.lax.dot_general(
        patches,
        w_ref[...].astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # (W_out, F)

    if relu:
        acc = jnp.maximum(acc, 0)
    if requant:
        acc = jnp.clip(jnp.right_shift(acc, REQUANT_SHIFT), I8_MIN, I8_MAX)
    o_ref[0, :, :] = acc


def conv2d_stream(x, w, *, stride: int = 1, padding: int = 1, relu: bool = True,
                  requant: bool = True, interpret: bool = True):
    """Line-buffer streaming conv via Pallas.

    x: (H, W, C) int8; w: (F, K, K, C) int8.
    Returns int8 (H_out, W_out, F) if requant else int32 accumulators.
    """
    h, wid, c = x.shape
    f, k, _, _ = w.shape
    h_out = (h + 2 * padding - k) // stride + 1
    w_out = (wid + 2 * padding - k) // stride + 1

    xp = jnp.pad(x, ((padding, padding), (padding, padding), (0, 0)))
    # Weights flattened to (K*K*C, F) once, matching the patch layout.
    wf = jnp.transpose(w, (1, 2, 3, 0)).reshape(k * k * c, f)

    kern = functools.partial(
        _conv_row_kernel, k=k, stride=stride, w_out=w_out, relu=relu, requant=requant
    )
    out = pl.pallas_call(
        kern,
        grid=(h_out,),
        in_specs=[
            # Whole padded map visible; the kernel reads only its K-row slab.
            pl.BlockSpec(xp.shape, lambda r: (0, 0, 0)),
            pl.BlockSpec(wf.shape, lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w_out, f), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, f), jnp.int32),
        interpret=interpret,
    )(xp, wf)
    if requant:
        out = out.astype(jnp.int8)
    return out


def vmem_footprint_bytes(h: int, w: int, c: int, k: int, f: int,
                         padding: int = 1) -> dict:
    """Estimate the per-grid-step VMEM residency of the slab schedule.

    This is the TPU analogue of the paper's BRAM line-buffer sizing
    ((K-1) x W x C on the FPGA). Reported in EXPERIMENTS.md §Perf.
    """
    w_pad = w + 2 * padding
    slab = k * w_pad * c * 4            # int32-widened K-row slab
    weights = k * k * c * f             # int8 flattened weights
    patches = w * k * k * c * 4         # patch matrix
    out_row = w * f * 4                 # one int32 output row
    return {
        "slab_bytes": slab,
        "weight_bytes": weights,
        "patch_bytes": patches,
        "out_row_bytes": out_row,
        "total_bytes": slab + weights + patches + out_row,
    }
