"""Pallas tiled int8 matmul for the Linear / Feed-Forward paper kernels.

The FPGA design streams activation rows through the linear node while the
weight matrix stays resident (the paper's regular-reduction node: a data
line is buffered, reduced against the constant operand, and the result is
streamed out). The TPU mapping tiles M into row blocks: each grid step
holds one `(BM, K)` activation tile plus the whole `(K, N)` weight panel
in VMEM and performs an MXU matmul — the weight panel is the analogue of
the FPGA node's resident coefficient buffer.

interpret=True only (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import I8_MAX, I8_MIN, REQUANT_SHIFT


def _mm_kernel(x_ref, w_ref, o_ref, *, relu: bool, requant: bool):
    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    if relu:
        acc = jnp.maximum(acc, 0)
    if requant:
        acc = jnp.clip(jnp.right_shift(acc, REQUANT_SHIFT), I8_MIN, I8_MAX)
    o_ref[...] = acc


def matmul_stream(x, w, *, block_m: int = 64, relu: bool = True,
                  requant: bool = True, interpret: bool = True):
    """x (M, K) int8 @ w (K, N) int8, streamed over M row-tiles.

    Returns (M, N) int8 if requant else int32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm = min(block_m, m)
    assert m % bm == 0, f"M={m} must be divisible by block_m={bm}"

    kern = functools.partial(_mm_kernel, relu=relu, requant=requant)
    out = pl.pallas_call(
        kern,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # weight panel resident
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x, w)
    if requant:
        out = out.astype(jnp.int8)
    return out
