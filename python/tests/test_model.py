"""L2 model tests: Pallas-backed paper kernels vs the oracle composition,
artifact variant metadata, and HLO lowering smoke checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, aot
from compile.kernels import ref


def run_both(name, size, shape):
    x32 = jnp.asarray(ref.det_tensor(model.SEED_INPUT, shape), dtype=jnp.int32)
    pallas_fn = model.build(name, size, use_pallas=True)
    oracle_fn = model.build(name, size, use_pallas=False)
    (got,) = pallas_fn(x32)
    (want,) = oracle_fn(x32)
    return np.array(got), np.array(want)


@pytest.mark.parametrize("name", ["conv_relu", "cascade", "residual"])
def test_conv_kernels_pallas_vs_oracle(name):
    got, want = run_both(name, 32, (32, 32, model.CONV_C))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", ["linear", "feedforward"])
def test_linear_kernels_pallas_vs_oracle(name):
    got, want = run_both(name, 0, (model.LIN_M, model.LIN_K))
    np.testing.assert_array_equal(got, want)


def test_residual_is_a_diamond():
    # The residual output must differ from plain cascade output: the skip
    # path has to contribute. Guards against accidentally dropping the add.
    shape = (16, 16, model.CONV_C)
    x32 = jnp.asarray(ref.det_tensor(model.SEED_INPUT, shape), dtype=jnp.int32)
    (res,) = model.build("residual", 16, use_pallas=False)(x32)
    w1, w2 = model.conv_weights(2)
    x = x32.astype(jnp.int8)
    chain = ref.requantize(ref.conv2d_i8(ref.conv_relu_i8(x, w1), w2))
    assert np.array(res).tolist() != np.array(chain.astype(jnp.int32)).tolist()


def test_outputs_in_int8_range():
    for name, size, shape in model.artifact_variants():
        if size == 224:
            continue  # covered by the 32x32 variants; skip slow interpret runs
        x32 = jnp.asarray(ref.det_tensor(model.SEED_INPUT, shape), dtype=jnp.int32)
        (y,) = model.build(name, size, use_pallas=False)(x32)
        y = np.array(y)
        assert y.min() >= ref.I8_MIN and y.max() <= ref.I8_MAX, name


def test_artifact_variants_cover_paper_table2():
    keys = {f"{n}_{s}" for n, s, _ in model.artifact_variants()}
    assert {"conv_relu_32", "conv_relu_224", "cascade_32", "cascade_224",
            "residual_32", "residual_224", "linear_0", "feedforward_0"} <= keys


def test_out_shape_matches_eval():
    assert aot.out_shape("conv_relu", 32) == (32, 32, model.CONV_F)
    assert aot.out_shape("linear", 0) == (model.LIN_M, model.LIN_N)


def test_hlo_text_lowering_smoke():
    text = aot.lower_variant("conv_relu", 8, (8, 8, model.CONV_C))
    assert text.startswith("HloModule")
    assert "s32[8,8,8]" in text          # int32 boundary types
    assert "s8[" in text                 # int8 compute inside


def test_hlo_lowering_is_deterministic():
    a = aot.lower_variant("linear", 0, (model.LIN_M, model.LIN_K))
    b = aot.lower_variant("linear", 0, (model.LIN_M, model.LIN_K))
    assert a == b


def test_weights_are_baked_constants():
    # The lowered module must have exactly one parameter (the input);
    # weights are constants — Rust never feeds them.
    text = aot.lower_variant("conv_relu", 8, (8, 8, model.CONV_C))
    entry = [l for l in text.splitlines() if "ENTRY" in l]
    assert entry, "no ENTRY computation"
    params = [l for l in text.split("ENTRY", 1)[1].splitlines() if "parameter(" in l]
    assert len(params) == 1, params


# ---------------------------------------------------------------------------
# extension workload: tiny_cnn (conv-pool-conv-pool)
# ---------------------------------------------------------------------------

def test_maxpool_semantics():
    x = jnp.asarray(ref.det_tensor(3, (6, 6, 2)))
    y = np.array(ref.maxpool2d_i8(x, 2, 2))
    assert y.shape == (3, 3, 2)
    xn = np.array(x)
    for r in range(3):
        for c in range(3):
            for ch in range(2):
                want = xn[2 * r : 2 * r + 2, 2 * c : 2 * c + 2, ch].max()
                assert y[r, c, ch] == want


def test_tiny_cnn_pallas_vs_oracle():
    got, want = run_both("tiny_cnn", 32, (32, 32, 4))
    np.testing.assert_array_equal(got, want)
    assert got.shape == (8, 8, 8)


def test_tiny_cnn_artifact_lowering():
    text = aot.lower_variant("tiny_cnn", 32, (32, 32, 4))
    assert text.startswith("HloModule")
    assert "constant({...})" not in text, "constants must not be elided"
