"""Round-trip tests for the JSON model schema shared with the Rust
front-end (rust/src/ir/json.rs::import_model), including the tile-grid
metadata consumed by the halo-aware tiling subsystem (rust/src/tiling/)."""

import json

import pytest

from compile import model


CHAIN_KERNELS = ["conv_relu", "cascade", "tiny_cnn", "linear", "feedforward"]


@pytest.mark.parametrize("name", CHAIN_KERNELS)
def test_json_model_roundtrips_through_json(name):
    size = 0 if name in ("linear", "feedforward") else 32
    doc = model.json_model(name, size)
    again = json.loads(json.dumps(doc))
    assert again == doc


@pytest.mark.parametrize("name", CHAIN_KERNELS)
def test_json_model_schema_keys(name):
    size = 0 if name in ("linear", "feedforward") else 32
    doc = model.json_model(name, size)
    assert doc["name"] == f"{name}_{size}"
    assert doc["input"]["dtype"] == "i8"
    assert doc["input"]["shape"] == list(model.input_shape(name, size))
    assert "tiling" not in doc, "no hint unless requested"
    for layer in doc["layers"]:
        assert layer["op"] in ("conv2d", "maxpool2d", "linear")
        if layer["op"] == "conv2d":
            # exactly the keys rust's import_model reads
            assert {"filters", "kernel", "stride", "pad", "seed"} <= set(layer)
            assert layer["activation"] in ("relu", "none")
        if layer["op"] == "linear":
            assert "features" in layer and "seed" in layer
        if layer["op"] in ("conv2d", "linear"):
            # ROM-accounting metadata: counts only, never tensor data
            assert layer["weight_bits"] == 8
            assert layer["weight_elems"] > 0


@pytest.mark.parametrize("name", CHAIN_KERNELS)
def test_weight_metadata_matches_layer_geometry(name):
    """weight_elems must equal the element count of the weight tensor the
    Rust importer derives from the layer chain (its ROM accounting keys
    off these numbers when no tensor data ships)."""
    size = 0 if name in ("linear", "feedforward") else 32
    doc = model.json_model(name, size)
    shape = list(model.input_shape(name, size))
    for layer in doc["layers"]:
        if layer["op"] == "conv2d":
            f, k, c = layer["filters"], layer["kernel"], shape[2]
            assert layer["weight_elems"] == f * k * k * c
            assert layer["weight_bits"] == 8
            shape = [shape[0], shape[1], f]  # stride-1 same padding
        elif layer["op"] == "maxpool2d":
            k, s = layer["kernel"], layer["stride"]
            shape = [(shape[0] - k) // s + 1, (shape[1] - k) // s + 1, shape[2]]
        elif layer["op"] == "linear":
            assert layer["weight_elems"] == shape[1] * layer["features"]
            assert layer["weight_bits"] == 8
            shape = [shape[0], layer["features"]]
    # no layer ever carries raw weight values
    for layer in doc["layers"]:
        assert "data" not in layer and "weights" not in layer


def test_tiling_metadata_carried():
    doc = model.json_model("conv_relu", 512, tile_width=64, max_tiles=16)
    assert doc["tiling"] == {"axis": "width", "tile_width": 64, "max_tiles": 16}
    # survives serialization bit-exactly
    again = json.loads(json.dumps(doc))
    assert again["tiling"] == doc["tiling"]
    # partial hints keep only the given keys
    doc2 = model.json_model("conv_relu", 512, tile_width=64)
    assert doc2["tiling"] == {"axis": "width", "tile_width": 64}


def test_grid_tiling_metadata_carried():
    # a tile_height upgrades the hint to the 2-D grid form consumed by
    # the stride-aware tile-grid subsystem
    doc = model.json_model("conv_relu", 512, tile_width=64, tile_height=128,
                           max_tiles=32)
    assert doc["tiling"] == {
        "axis": "grid", "tile_width": 64, "tile_height": 128, "max_tiles": 32,
    }
    again = json.loads(json.dumps(doc))
    assert again["tiling"] == doc["tiling"]
    # height-only hints are valid too (row strips)
    doc2 = model.json_model("tiny_cnn", 32, tile_height=4)
    assert doc2["tiling"] == {"axis": "grid", "tile_height": 4}


def test_weight_seeds_match_rust_prng_contract():
    doc = model.json_model("cascade", 32)
    convs = [l for l in doc["layers"] if l["op"] == "conv2d"]
    assert [l["seed"] for l in convs] == [model.SEED_W1, model.SEED_W2]
    ff = model.json_model("feedforward", 0)
    assert [l["seed"] for l in ff["layers"]] == [model.SEED_W1, model.SEED_W2]


def test_residual_not_expressible():
    with pytest.raises(ValueError):
        model.json_model("residual", 32)


def test_conv_geometry_matches_kernel_constants():
    doc = model.json_model("conv_relu", 32)
    (conv,) = doc["layers"]
    assert conv["filters"] == model.CONV_F
    assert conv["kernel"] == model.CONV_K
    assert conv["pad"] == model.CONV_K // 2
    assert conv["stride"] == 1


def test_scale_out_flag_passthrough():
    # The aot driver forwards the Rust CLI's scale-out flags verbatim
    # (rust/src/main.rs: --design-cache / --workers / --shard / --spool).
    from compile import aot

    assert aot.scale_out_args() == []
    argv = aot.scale_out_args(
        design_cache="/tmp/dc", workers=4, shard="1/2", spool="/tmp/spool"
    )
    assert argv == [
        "--design-cache", "/tmp/dc",
        "--workers", "4",
        "--shard", "1/2",
        "--spool", "/tmp/spool",
    ]

    imp = aot.ming_import_argv(
        "out/conv_relu_32.model.json", device="kv260", design_cache="/tmp/dc"
    )
    assert imp[:4] == ["ming", "import", "--model", "out/conv_relu_32.model.json"]
    assert imp[4:] == ["--device", "kv260", "--design-cache", "/tmp/dc"]

    sweep = aot.ming_sweep_argv(
        estimate_only=True, shard="0/2", spool="/tmp/spool", design_cache="/tmp/dc"
    )
    assert sweep[:2] == ["ming", "table2"]
    assert "--estimate-only" in sweep
    # shard/spool/cache ride through in the documented order
    assert sweep[-6:] == [
        "--design-cache", "/tmp/dc", "--shard", "0/2", "--spool", "/tmp/spool",
    ]
