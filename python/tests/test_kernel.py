"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Shape/parameter sweeps play the role hypothesis would (no third-party
property-testing package in this environment): the grids below enumerate
the parameter lattice rather than sampling it, which is strictly stronger
for these small spaces.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref, conv2d_stream, matmul_stream
from compile.kernels.conv2d_stream import vmem_footprint_bytes


def conv_pair(seed, h, w, c, f, k):
    x = jnp.asarray(ref.det_tensor(seed, (h, w, c)))
    wt = jnp.asarray(ref.det_tensor(seed + 100, (f, k, k, c)))
    return x, wt


# ---------------------------------------------------------------------------
# conv2d_stream sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w", [(4, 4), (5, 7), (8, 8), (16, 12), (32, 32)])
@pytest.mark.parametrize("c,f", [(1, 1), (3, 4), (8, 8)])
def test_conv_shapes(h, w, c, f):
    x, wt = conv_pair(h * 31 + w, h, w, c, f, 3)
    got = conv2d_stream(x, wt)
    want = ref.kernel_conv_relu(x, wt)
    np.testing.assert_array_equal(np.array(got), np.array(want))


@pytest.mark.parametrize("k,pad", [(1, 0), (3, 1), (5, 2)])
def test_conv_kernel_sizes(k, pad):
    x, wt = conv_pair(k, 12, 12, 4, 4, k)
    got = conv2d_stream(x, wt, padding=pad)
    want = ref.requantize(ref.relu_i32(ref.conv2d_i8(x, wt, padding=pad)))
    np.testing.assert_array_equal(np.array(got), np.array(want))


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_strides(stride):
    x, wt = conv_pair(stride * 13, 16, 16, 4, 4, 3)
    got = conv2d_stream(x, wt, stride=stride)
    want = ref.requantize(ref.relu_i32(ref.conv2d_i8(x, wt, stride=stride)))
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_conv_no_relu_no_requant():
    x, wt = conv_pair(5, 8, 8, 4, 4, 3)
    got = conv2d_stream(x, wt, relu=False, requant=False)
    want = ref.conv2d_i8(x, wt)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_conv_requant_saturates():
    # All-max inputs must exercise the int8 clamp, not wrap around.
    x = jnp.full((8, 8, 8), 127, dtype=jnp.int8)
    wt = jnp.full((8, 3, 3, 8), 127, dtype=jnp.int8)
    got = np.array(conv2d_stream(x, wt))
    assert got.max() == ref.I8_MAX
    want = np.array(ref.kernel_conv_relu(x, wt))
    np.testing.assert_array_equal(got, want)


def test_conv_negative_inputs_relu_zeroes():
    x = jnp.full((6, 6, 2), -128, dtype=jnp.int8)
    wt = jnp.full((2, 3, 3, 2), 127, dtype=jnp.int8)
    got = np.array(conv2d_stream(x, wt))
    # interior pixels: all-negative accumulators -> relu -> 0
    assert (got[2:-2, 2:-2, :] == 0).all()


# ---------------------------------------------------------------------------
# matmul_stream sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (64, 32, 16), (128, 64, 32), (512, 128, 128)])
def test_matmul_shapes(m, k, n):
    x = jnp.asarray(ref.det_tensor(m + n, (m, k)))
    w = jnp.asarray(ref.det_tensor(k, (k, n)))
    got = matmul_stream(x, w)
    want = ref.requantize(ref.relu_i32(ref.linear_i8(x, w)))
    np.testing.assert_array_equal(np.array(got), np.array(want))


@pytest.mark.parametrize("bm", [8, 16, 64])
def test_matmul_block_sizes(bm):
    x = jnp.asarray(ref.det_tensor(3, (64, 32)))
    w = jnp.asarray(ref.det_tensor(4, (32, 32)))
    got = matmul_stream(x, w, block_m=bm)
    want = ref.requantize(ref.relu_i32(ref.linear_i8(x, w)))
    np.testing.assert_array_equal(np.array(got), np.array(want))


def test_matmul_rejects_indivisible_m():
    x = jnp.asarray(ref.det_tensor(3, (10, 8)))
    w = jnp.asarray(ref.det_tensor(4, (8, 8)))
    with pytest.raises(AssertionError):
        matmul_stream(x, w, block_m=4)  # 10 % 4 != 0


# ---------------------------------------------------------------------------
# quantization contract invariants
# ---------------------------------------------------------------------------

def test_requantize_floor_rounding():
    acc = jnp.asarray([-65, -64, -1, 0, 1, 63, 64, 65], dtype=jnp.int32)
    got = np.array(ref.requantize(acc))
    # arithmetic >> floors toward -inf: -65>>6 == -2, -1>>6 == -1
    np.testing.assert_array_equal(got, [-2, -1, -1, 0, 0, 0, 1, 1])


def test_requantize_clamps():
    acc = jnp.asarray([1 << 20, -(1 << 20)], dtype=jnp.int32)
    got = np.array(ref.requantize(acc))
    np.testing.assert_array_equal(got, [ref.I8_MAX, ref.I8_MIN])


def test_det_tensor_deterministic_and_full_range():
    a = ref.det_tensor(42, (1024,))
    b = ref.det_tensor(42, (1024,))
    np.testing.assert_array_equal(a, b)
    assert a.min() < -100 and a.max() > 100  # spans the int8 range
    assert ref.det_tensor(43, (1024,)).tolist() != a.tolist()


def test_vmem_footprint_model():
    fp = vmem_footprint_bytes(32, 32, 8, 3, 8)
    assert fp["total_bytes"] == fp["slab_bytes"] + fp["weight_bytes"] + fp["patch_bytes"] + fp["out_row_bytes"]
    # slab is the (K x W_pad x C) int32 line buffer analogue
    assert fp["slab_bytes"] == 3 * 34 * 8 * 4
    # footprint must be << 16 MiB VMEM for every paper size
    assert vmem_footprint_bytes(224, 224, 8, 3, 8)["total_bytes"] < 16 * 2**20
